"""End-to-end driver: train a ~100M-param smollm-family model for a few
hundred steps on synthetic data (CPU-feasible), with checkpointing.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is a thin wrapper over the production launcher
(repro.launch.train); pass --arch/--batch/--seq to explore. The ~100M
config: smollm trunk at 12 layers × d=512.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv = ["--arch", "smollm-360m", "--reduced100m"] + argv
    # translate the convenience flag into launcher args
    if "--reduced100m" in argv:
        argv.remove("--reduced100m")
        argv += ["--steps", "300", "--batch", "8", "--seq", "128",
                 "--ckpt-dir", "/tmp/repro_ckpt_100m"]
        # ~100M params: tweak via the reduced config path below
        import repro.configs as C

        base = C.get_config("smollm-360m")
        cfg100 = base.replace(n_layers=12, d_model=512, n_heads=8,
                              n_kv_heads=4, head_dim=64, d_ff=1536,
                              vocab=8192, param_dtype="float32",
                              compute_dtype="float32", remat=False,
                              act_shard="none")
        C.ARCHS["smollm-100m"] = cfg100
        argv = ["--arch", "smollm-100m"] + argv
    raise SystemExit(main(argv))
