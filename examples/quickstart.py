"""Quickstart: the paper's workflow in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Deduplicates a 5k-product catalog three ways (Basic / BlockSplit /
PairRange) and prints the skew story: identical matches, wildly
different load balance.
"""
import numpy as np

from repro.er import ERConfig, make_products, run_er

ds = make_products(5_000, seed=0)
print(f"dataset: {ds.n} product titles, {len(ds.true_pairs)} injected duplicates")

for strategy in ("basic", "block_split", "pair_range"):
    cfg = ERConfig(strategy=strategy, r=16, m=8)
    res = run_er(ds.titles, cfg)
    recall = len(res.matches & ds.true_pairs) / len(ds.true_pairs)
    loads = res.reducer_pairs
    print(f"{strategy:12s} pairs={res.total_pairs:>9,} "
          f"matches={len(res.matches):>5} recall={recall:.3f} "
          f"max/mean load={loads.max() / max(loads.mean(), 1):>6.2f} "
          f"modeled-makespan={res.makespan_seconds:.2f}s "
          f"map-output={res.map_output_size}")

print("\nthe point: one block holds ~70% of all pairs — Basic pins it to a "
      "single reducer;\nBlockSplit/PairRange split it, with identical match "
      "output.")
