"""Serving example: batched prefill + autoregressive decode with a KV
cache, optionally resuming weights from examples/train_lm.py.

    PYTHONPATH=src python examples/serve_lm.py [--tokens 32] [--batch 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.models import get_model
from repro.serve import generate


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=32)
    p.add_argument("--temperature", type=float, default=0.8)
    args = p.parse_args()

    cfg = reduced(get_config(args.arch))
    mod = get_model(cfg)
    params = mod.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))}

    t0 = time.perf_counter()
    out = generate(params, cfg, batch, max_new_tokens=args.tokens,
                   temperature=args.temperature, key=jax.random.key(1))
    dt = time.perf_counter() - t0
    total = args.batch * args.tokens
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.tokens}")
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print("sample token ids:", np.asarray(out[0])[:16].tolist())


if __name__ == "__main__":
    main()
