"""Chaos drill quickstart (DESIGN.md §Fault tolerance): replay a seeded
failure script — kills, stragglers, transient errors, corrupted shards —
against a supervised 8-device run and watch tile-granular recovery
return the exact quiet match set; then point the same chaos at a
resident ``ERService`` and watch the circuit breaker evict the dead
device and re-admit it after its revive.

    PYTHONPATH=src python examples/chaos_drill.py
"""
import numpy as np

from repro.core import compute_bdm, plan_block_split
from repro.er import ERService, ServiceConfig, make_products
from repro.er.blocking import exponential_block_ids
from repro.er.compiler import (FaultEvent, FaultInjector, FaultScript,
                               execute, execute_supervised, lower,
                               plan_to_job)

N_DEV, THRESH = 8, 0.4

# ---- the paper's Fig. 9 robustness workload at s = 1.0 -------------------
rng = np.random.default_rng(9)
n = 2_000
bid = exponential_block_ids(n, b=100, s=1.0, rng=rng)
bdm = compute_bdm(bid, np.zeros(n, np.int64), int(bid.max()) + 1, 1)
catalog = lower(plan_to_job(plan_block_split(bdm, 32)), 64, 64)
feats = rng.normal(size=(n, 64)).astype(np.float32)
feats /= np.linalg.norm(feats, axis=1, keepdims=True)

quiet = set(zip(*map(np.ndarray.tolist,
                     execute(catalog, feats, threshold=THRESH))))
print(f"quiet run: {catalog.num_tiles} tiles, {len(quiet)} survivors")

# ---- executor drill: a seeded random script, replayed --------------------
script = FaultScript.random(seed=7, n_dev=N_DEV, n_events=6,
                            max_step=24, straggle_delay=1e6,
                            allow_revive=True)
for e in script.events:
    print(f"  step {e.step:2d}: {e.kind:9s} device {e.device}"
          + (f" (+{e.delay:g}s)" if e.delay else ""))
ra, rb, rep = execute_supervised(
    catalog, feats, threshold=THRESH, n_dev=N_DEV, shard_deadline=120.0,
    max_retries=8, backoff=0.0, injector=FaultInjector(script, seed=7))
assert set(zip(ra.tolist(), rb.tolist())) == quiet     # exact recovery
assert rep.coverage == 1.0 and rep.retries <= 8
failed = [r for r in rep.records if r.status != "ok"]
print(f"recovered in {rep.rounds} rounds: {len(failed)} failed shards "
      f"({', '.join(sorted({r.status for r in failed}) or ['none'])}), "
      f"{rep.recovered_tiles} tiles re-executed, coverage {rep.coverage}")
print(f"final healthy mask: {rep.healthy.astype(int).tolist()}")

# ---- service drill: circuit breaker evicts, probe re-admits --------------
ds = make_products(400, seed=3)
svc = ERService(ds.titles[:320], ServiceConfig(
    feature_dim=128, max_len=48, r=8, m=4, query_buckets=(16,),
    tile_chunk=64, exec_devices=N_DEV, backoff_s=0.0,
    breaker_threshold=1, breaker_cooldown_s=0.0))
svc.set_fault_injector(FaultInjector(FaultScript(events=(
    FaultEvent("kill", 2, 0), FaultEvent("corrupt", 4, 4),
    FaultEvent("revive", 2, 25)), n_dev=N_DEV)))
for i in range(6):
    batch = ds.titles[320 + i * 13:320 + (i + 1) * 13]
    resp = svc.match(batch)
    print(f"batch {i}: {len(resp)} matches, attempts {resp.attempts}, "
          f"coverage {resp.coverage}, evicted {sorted(svc._breaker_open)}")
s = svc.stats
assert s["degraded"] == 0
print(f"\nbreaker: {s['breaker_evictions']} evictions, "
      f"{s['breaker_readmissions']} readmissions; "
      f"{s['retries']} request retries, "
      f"{s['recovered_tiles']} tiles recovered — every response exact")
