"""Resident ER service quickstart: ingest a product corpus once, then
answer match micro-batches from the warm compiled-shape cache — the
serving analog of the batch ``run_er`` pipeline (paper Fig. 2), built on
the two-source R × S plans of Appendix I.

    PYTHONPATH=src python examples/match_service.py
"""
import numpy as np

from repro.er import ERService, ServiceConfig, compile_counter, make_products

CORPUS_N, BATCHES = 3_000, 8

ds = make_products(CORPUS_N, seed=0)

# Ingest once: features + block layout go resident, the BDM stays host-side.
cfg = ServiceConfig(feature_dim=128, max_len=48, r=16, m=4,
                    query_buckets=(8, 32, 128), tile_chunk=128)
svc = ERService(ds.titles, cfg)
print(f"ingested {svc.n_corpus} entities, {svc.bdm.shape[0]} blocks, "
      f"{svc.ingest_seconds*1e3:.0f} ms")

with compile_counter() as warm:
    svc.warmup()
print(f"warmup compiled everything in {warm.count} XLA compilations")

# Steady state: perturbed corpus titles (≈ near-duplicates), a null-key
# query, and a never-seen block — zero new compilations from here on.
rng = np.random.default_rng(1)
with compile_counter() as steady:
    for i in range(BATCHES):
        size = int(rng.integers(1, 100))
        batch = []
        for _ in range(size):
            t = ds.titles[int(rng.integers(0, len(ds.titles)))]
            s = list(t)
            s[int(rng.integers(3, len(s)))] = "x"
            batch.append("".join(s))
        if i == 3:
            batch[0] = ""                        # null key → match_⊥ path
        if i == 5:
            batch[0] = "@@@ brand new block"     # grows the BDM
        found = svc.match(batch)
        print(f"batch {i}: {len(batch):3d} queries → {len(found):3d} matches")
        for c, q in sorted(found)[:2]:
            print(f"    corpus[{c}] {ds.titles[c]!r}  ≈  query {batch[q]!r}")

s = svc.stats
print(f"\nserved {s['queries']} queries in {s['batches']} batches, "
      f"{s['matches']} matches, {s['planned_pairs']:,} planned cross pairs, "
      f"{s['queries']/max(s['seconds'],1e-9):,.0f} queries/s, "
      f"{steady.count} steady-state recompiles")
print("bucket hits:", s["bucket_hits"])
print("traffic skew (top-5 blocks):",
      np.sort(svc.traffic_bdm[:, 0])[::-1][:5].tolist())
