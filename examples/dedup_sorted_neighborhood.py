"""Sorted Neighborhood quickstart (arXiv:1010.3053 meets the load-balanced
executor): dedup a product catalog by sliding a window over the title
sort order instead of blocking on a key prefix.

    PYTHONPATH=src python examples/dedup_sorted_neighborhood.py

SN trades the block distribution's skew problem for a fixed O(n·w) band:
the planner range-partitions the band's pair-index space into r balanced
reduce tasks (imbalance ≈ 1 by construction), and the band compiles to
diagonal-hugging MXU tiles scored by the same fused catalog kernel the
blocking strategies use.
"""
import numpy as np

from repro.er import ERConfig, make_products, run_er

ds = make_products(8_000, seed=0)

last = None
for window in (5, 10, 50):
    cfg = ERConfig(strategy="sorted_neighborhood", window=window, r=32)
    last = res = run_er(ds.titles, cfg)
    recall = len(res.matches & ds.true_pairs) / len(ds.true_pairs)
    loads = res.reducer_pairs
    print(f"w={window:3d}  band pairs={res.total_pairs:>9,}  "
          f"map kv={res.map_output_size:>7,}  "
          f"imbalance={loads.max() / loads.mean():.3f}  "
          f"matches={len(res.matches):>5}  recall={recall:.3f}")

# compare against the blocking baseline: same matcher, different search space
base = run_er(ds.titles, ERConfig(strategy="pair_range", r=32))
recall = len(base.matches & ds.true_pairs) / len(ds.true_pairs)
print(f"\npair_range baseline: {base.total_pairs:,} pairs, recall={recall:.3f}"
      f" — SN at w=50 searches {last.total_pairs / max(base.total_pairs, 1):.1f}×"
      f" that, but needs no blocking key and cannot be skewed by one")
