"""Two-source entity resolution (paper Appendix I): match a 'store A'
catalog against a 'store B' catalog — only cross-source pairs compared,
with PairRange balancing over the rectangular |Φ_R|×|Φ_S| enumeration.

    PYTHONPATH=src python examples/dedup_two_sources.py
"""
import numpy as np

from repro.core import compute_bdm
from repro.core.two_source import (TwoSourceBDM, plan_block_split_2src,
                                   plan_pair_range_2src, pairs_of_range_2src)
from repro.er import make_products
from repro.er.blocking import prefix_block_ids
from repro.er.encode import encode_titles, ngram_features
from repro.er.similarity import edit_similarity

R_SIZE, S_SIZE, R_TASKS = 3_000, 2_000, 12

# two overlapping catalogs: B perturbs a slice of A's titles
a = make_products(R_SIZE, seed=0)
b = make_products(S_SIZE, seed=0)      # same generator seed → overlaps
r_titles, s_titles = a.titles, b.titles

# shared dense block space over both sources (3-char prefix)
all_ids, names = prefix_block_ids(r_titles + s_titles, a.prefix_len)
rid, sid = all_ids[:len(r_titles)], all_ids[len(r_titles):]
nb = int(all_ids.max()) + 1
bdm2 = TwoSourceBDM(
    bdm_r=compute_bdm(rid, np.zeros_like(rid), nb, 1),
    bdm_s=compute_bdm(sid, np.zeros_like(sid), nb, 1))

plan = plan_pair_range_2src(bdm2, R_TASKS)
print(f"R={len(r_titles)} S={len(s_titles)} blocks={nb} "
      f"cross pairs={plan.total_pairs:,} "
      f"pairs/reducer={plan.reducer_pairs.tolist()[:6]}…")

# order each source's entities into the blocked layout
r_order = np.argsort(rid, kind="stable")
s_order = np.argsort(sid, kind="stable")
rc, rl = encode_titles([r_titles[i] for i in r_order])
sc, sl = encode_titles([s_titles[i] for i in s_order])
rf = ngram_features(rc, lengths=rl)
sf = ngram_features(sc, lengths=sl)

matches = []
for k in range(R_TASKS):
    blk, x, y, rr, ss = pairs_of_range_2src(plan, k)
    if rr.size == 0:
        continue
    cos = np.einsum("pd,pd->p", rf[rr], sf[ss])
    cand = np.flatnonzero(cos >= 0.55)
    if cand.size == 0:
        continue
    sim = np.asarray(edit_similarity(rc[rr[cand]], rl[rr[cand]],
                                     sc[ss[cand]], sl[ss[cand]]))
    hit = cand[sim >= 0.8]
    matches.extend((int(r_order[rr[i]]), int(s_order[ss[i]])) for i in hit)

print(f"cross-source matches: {len(matches)}; sample:")
for ri, si in matches[:5]:
    print(f"  A[{ri}] {r_titles[ri]!r}  ≈  B[{si}] {s_titles[si]!r}")
