"""Shared benchmark utilities.

Methodology note (EXPERIMENTS.md): this container is one CPU, so the
paper's multi-node wall-clocks are validated two ways —
  (i)  EXACT work-distribution math: pairs per reduce task from the
       plans (the paper's own balance metric), and
  (ii) MEASURED vectorized matching on data that fits one host, giving
       a cost-per-pair that converts loads into modeled makespans:
           makespan(n) = max_k(load_k) · cost_per_pair + overhead(BDM).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def save_rows(name: str, rows: List[Dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    return path


def print_table(title: str, rows: List[Dict], cols=None):
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    cols = cols or list(rows[0])
    widths = {c: max(len(str(c)), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    print("  ".join(str(c).ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3g}"
        return f"{v:.4g}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
