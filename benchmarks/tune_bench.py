"""Tile-geometry autotuning: lattice sweep + occupancy model vs measured
stage-1 throughput on the Fig. 9 skew workload.

Two legs, both at the paper's robustness point s = 1.0 (block sizes
|Φ_k| ∝ e^{−s·k}, the regime where a fixed 128×128 tile wastes most of
its cells on small blocks):

  * **lattice sweep** — lower the BlockSplit job once per VMEM-feasible
    geometry in ``GEOMETRY_LATTICE``, score the identical feature matrix
    through each catalog, and assert every geometry reproduces the EXACT
    128×128 match set. Measured seconds feed a geometry-keyed
    :class:`GeometryCostModel`; a second ``autotune`` pass with that
    feedback must agree with the measured argmin.
  * **service leg** — a resident :class:`ERService` with
    ``autotune_tiles=True`` sweeps its (smaller) lattice during
    ``warmup()``, pins the winner, and then serves steady-state traffic
    with ZERO XLA compiles (the zero-steady-state-recompile contract
    must survive geometry switching).

Asserted invariants (the PR-9 autotuning contract):
  * match-set equality across EVERY swept geometry (tile geometry is an
    execution detail, never a semantics knob);
  * the statically autotuned geometry is >= 1.2x stage-1 throughput over
    the fixed 128×128 baseline at skew s=1.0;
  * feedback-ranked autotune picks the measured-fastest geometry;
  * 0 steady-state compiles after an autotuning warmup.

    PYTHONPATH=src python -m benchmarks.tune_bench [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import compute_bdm, plan_block_split
from repro.er import ERService, ServiceConfig, compile_counter
from repro.er.blocking import exponential_block_ids
from repro.er.compiler import (GeometryCostModel, autotune, lower,
                               plan_to_job, score_catalog)

from .common import print_table, save_rows, timer
from .serve_bench import skewed_corpus

SPEEDUP_BAR = 1.2          # autotuned vs fixed 128x128, stage-1 pairs/s
BASELINE = (128, 128)


def _skew_workload(n: int, d: int, r: int, m: int, s: float, seed: int = 7):
    rng = np.random.default_rng(seed)
    bid = exponential_block_ids(n, b=100, s=s, rng=rng)
    feats = rng.standard_normal((n, d)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    order = np.argsort(bid, kind="stable")
    feats, bid = feats[order], bid[order]
    sizes = np.bincount(bid)
    part = np.arange(n, dtype=np.int64) % m
    bdm = compute_bdm(bid, part, int(sizes.shape[0]), m)
    return feats, plan_to_job(plan_block_split(bdm, r))


def _bench_geometry(feats, job, bm, bn, threshold, impl, repeats=2):
    cat = lower(job, bm, bn)
    score_catalog(feats, cat, threshold=threshold, impl=impl)   # compile
    best = float("inf")
    for _ in range(repeats):
        with timer() as t:
            ra, rb = score_catalog(feats, cat, threshold=threshold, impl=impl)
        best = min(best, t.seconds)
    matches = {(min(a, b), max(a, b)) for a, b in zip(ra.tolist(), rb.tolist())}
    return best, matches


def run(n: int = 8_000, d: int = 256, r: int = 100, m: int = 20,
        svc_n: int = 4_000, quick: bool = False):
    if quick:
        n, svc_n = 3_000, 2_000
    import jax
    impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    threshold = 0.15
    feats, job = _skew_workload(n, d, r, m, s=1.0)

    # ---- leg 1: lattice sweep, match-set parity, 1.2x bar ----
    report = autotune(job, d=d)           # static occupancy/waste ranking
    feedback = GeometryCostModel()
    rows, match_sets, seconds = [], {}, {}
    for sc in report.scores:
        secs, matches = _bench_geometry(
            feats, job, sc.block_m, sc.block_n, threshold, impl)
        seconds[sc.geometry] = secs
        match_sets[sc.geometry] = matches
        feedback.observe(sc.geometry, sc.live_pairs, secs)
        rows.append({
            "geometry": f"{sc.block_m}x{sc.block_n}",
            "tiles": sc.tiles,
            "occupancy": round(sc.occupancy, 3),
            "waste_cells": sc.waste,
            "model_cost": round(sc.model_cost, 0),
            "seconds": round(secs, 4),
            "mpairs_per_s": round(sc.live_pairs / secs / 1e6, 2),
            "matches": len(matches),
        })
    base_set = match_sets[BASELINE]
    for geom, matches in match_sets.items():
        assert matches == base_set, \
            f"geometry {geom} changed the match set vs {BASELINE}"

    tuned = report.geometry
    speedup = seconds[BASELINE] / seconds[tuned]
    refit = autotune(job, d=d, feedback=feedback)
    measured_best = min(seconds, key=seconds.get)
    rows.sort(key=lambda r: r["seconds"])
    meta = {
        "n": n, "d": d, "skew_s": 1.0, "impl": impl,
        "autotuned": f"{tuned[0]}x{tuned[1]}",
        "speedup_vs_128": round(speedup, 2),
        "feedback_pick": f"{refit.geometry[0]}x{refit.geometry[1]}",
        "measured_best": f"{measured_best[0]}x{measured_best[1]}",
    }
    print_table(f"tune_bench — lattice sweep, Fig. 9 skew s=1.0 "
                f"(n={n}, d={d}, impl={impl})", rows)
    print("meta:", meta)
    assert speedup >= SPEEDUP_BAR, \
        f"autotuned {tuned} only {speedup:.2f}x vs fixed 128x128 " \
        f"(bar {SPEEDUP_BAR}x)"
    assert refit.geometry == measured_best, \
        f"feedback autotune picked {refit.geometry}, " \
        f"measured best was {measured_best}"

    # ---- leg 2: service autotune warmup, zero steady compiles ----
    titles, rng = skewed_corpus(svc_n, b=100, s=1.0)
    lattice = ((32, 32), (64, 64), (128, 128))
    cfg = ServiceConfig(feature_dim=128, max_len=48, r=32, m=8,
                        query_buckets=(8, 32), tile_chunk=256,
                        autotune_tiles=True, autotune_lattice=lattice)
    svc = ERService(titles, cfg)
    with compile_counter() as warm, timer() as t_warm:
        svc.warmup()
    with compile_counter() as steady, timer() as t_steady:
        nq = 0
        for _ in range(8):
            qs = [titles[int(rng.integers(0, len(titles)))] for _ in range(8)]
            svc.match(qs)
            nq += len(qs)
    svc_row = {
        "geometry": f"{svc.tile_geometry[0]}x{svc.tile_geometry[1]}",
        "lattice": len(lattice),
        "warmup_s": round(t_warm.seconds, 2),
        "warmup_compiles": warm.count,
        "steady_compiles": steady.count,
        "queries_per_s": round(nq / max(t_steady.seconds, 1e-9), 1),
    }
    print_table(f"tune_bench — ERService autotune warmup (n={svc_n})",
                [svc_row])
    assert steady.count == 0, \
        f"steady-state recompiles after autotuning warmup: {steady.count}"

    save_rows("tune_bench", [dict(r, **meta) for r in rows]
              + [dict(svc_row, leg="service")])
    return rows


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv)
