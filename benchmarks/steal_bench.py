"""Straggle drill for runtime-feedback scheduling (DESIGN.md
§Scheduling feedback loop): the paper's Fig. 9 robustness workload
(b = 100 blocks, |Φ_k| ∝ e^{−s·k}, s = 1.0) on 8 simulated devices, two
of which carry a seeded *sticky* straggle — every shard call on them
pays a fixed virtual delay, the persistent slow-node regime static LPT
cannot see.

Each strategy runs twice through ``execute_supervised`` with the SAME
dispatch quantum: once static (no stealing — the slow devices grind
through their full queues) and once with the EWMA feedback model and
mid-stream work stealing enabled. Both runs must return EXACTLY the
failure-free (quiet) survivor set; the steal run must cut the simulated
busy-time makespan by at least ``WIN_FLOOR`` (asserted — the CI bar),
because queued tiles migrate off the slow devices after the first
measured calls expose them.

Rows land in ``benchmarks/out/steal_bench.json``.

    PYTHONPATH=src python -m benchmarks.steal_bench [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.er.compiler import (EwmaCostModel, FaultEvent, FaultInjector,
                               FaultScript, execute, execute_supervised)

from .chaos_bench import N_DEV, THRESH, _pairs, _workload
from .common import print_table, save_rows, timer

SLOW_DEVICES = (1, 6)        # seeded stragglers (of N_DEV = 8)
SLOW_DELAY_S = 0.25          # virtual seconds added to EVERY call on them
QUANTUM = 8                  # dispatch batch size, identical in both modes
STEAL_FACTOR = 2.0           # steal when projected finish > 2× fleet median
WIN_FLOOR = 1.5              # asserted minimum static/steal makespan ratio


def _script() -> FaultScript:
    return FaultScript(events=tuple(
        FaultEvent("straggle", d, 0, delay=SLOW_DELAY_S, sticky=True)
        for d in SLOW_DEVICES), n_dev=N_DEV)


def _run(cat, feats, want, steal: bool):
    ra, rb, rep = execute_supervised(
        cat, feats, threshold=THRESH, n_dev=N_DEV, max_retries=2,
        backoff=0.0, injector=FaultInjector(_script()),
        steal_quantum=QUANTUM,
        steal_factor=STEAL_FACTOR if steal else None,
        feedback=EwmaCostModel(N_DEV) if steal else None)
    assert _pairs(ra, rb) == want, "diverged from the quiet match set"
    assert rep.coverage == 1.0 and rep.lost_tiles == 0
    return rep


def drill(n: int, r: int):
    cats, feats = _workload(n, r)
    rows = []
    for strat, cat in cats.items():
        want = _pairs(*execute(cat, feats, threshold=THRESH))
        reps = {}
        for mode in ("static", "steal"):
            with timer() as t:
                rep = reps[mode] = _run(cat, feats, want, mode == "steal")
            rows.append({
                "strategy": strat, "mode": mode, "tiles": cat.num_tiles,
                "steals": rep.steals, "stolen_tiles": rep.stolen_tiles,
                "makespan_s": round(rep.measured_makespan_s, 4),
                "injected_s": round(sum(rec.injected_delay
                                        for rec in rep.records), 4),
                "real_s": round(sum(rec.elapsed for rec in rep.records), 4),
                "wall_s": round(t.seconds, 4),
                "exact": True,
            })
        static, stolen = reps["static"], reps["steal"]
        assert static.steals == 0
        assert stolen.steals >= 1, (strat, "no steal ever triggered")
        win = static.measured_makespan_s / max(stolen.measured_makespan_s,
                                               1e-12)
        assert win >= WIN_FLOOR, (strat, win)
        rows.append({
            "strategy": strat, "mode": "win", "tiles": cat.num_tiles,
            "steals": stolen.steals, "stolen_tiles": stolen.stolen_tiles,
            "makespan_s": round(win, 2), "exact": True,
        })
    return rows


def run(n: int = 4_000, r: int = 32, quick: bool = False):
    if quick:
        n = 1_200
    rows = drill(n, r)
    print_table(
        f"steal_bench — sticky stragglers {list(SLOW_DEVICES)} "
        f"(+{SLOW_DELAY_S}s/call) over n={n}, s=1.0, n_dev={N_DEV}, "
        f"quantum={QUANTUM} (mode=win: makespan_s is static/steal ratio)",
        rows,
        cols=["strategy", "mode", "tiles", "steals", "stolen_tiles",
              "makespan_s", "injected_s", "real_s", "exact"])
    path = save_rows("steal_bench", rows)
    wins = [row["makespan_s"] for row in rows if row["mode"] == "win"]
    print(f"\nall strategies exact; makespan wins {wins} "
          f"(floor {WIN_FLOOR}×) — {path}")
    return rows


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv)
