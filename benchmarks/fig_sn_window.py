"""Sorted Neighborhood window sweep — the SN analog of the paper's
balance/map-output studies (arXiv:1010.3053 §5).

Sweeps w ∈ {10, 100, 1000} and reports, per window: exact band pair
count, planned reducer-load imbalance (max/mean — ≈ 1 by construction),
closed-form map-output size, band-catalog tile count, measured
match-phase wall clock through the fused catalog executor, and recall on
the generator's injected duplicates. Rows are recorded to
``benchmarks/out/fig_sn_window.json``.

    PYTHONPATH=src python -m benchmarks.fig_sn_window [--smoke]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.er import ERConfig, make_products, run_er

from .common import print_table, save_rows, timer

WINDOWS = (10, 100, 1000)


def run(n: int = 40_000, r: int = 32, quick: bool = False):
    if quick:
        n = 6_000
    ds = make_products(n)
    rows = []
    for w in WINDOWS:
        cfg = ERConfig(strategy="sorted_neighborhood", window=w, r=r)
        with timer() as t:
            res = run_er(ds.titles, cfg)
        loads = res.reducer_pairs
        recall = (len(res.matches & ds.true_pairs) / len(ds.true_pairs)
                  if ds.true_pairs else 0.0)
        rows.append({
            "n": ds.n, "w": w, "r": r,
            "pairs": res.total_pairs,
            "imbalance": round(float(loads.max() / max(loads.mean(), 1)), 4),
            "map_output": res.map_output_size,
            "tiles": res.extra.get("catalog_tiles", 0),
            "sort_s": round(res.bdm_seconds, 4),
            "match_s": round(float(res.reducer_seconds.sum()), 4),
            "wall_s": round(t.seconds, 4),
            "matches": len(res.matches),
            "recall": round(recall, 4),
        })
    print_table("SN window sweep — band size, balance, map output", rows)
    save_rows("fig_sn_window", rows)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-speed: small corpus, same window sweep")
    p.add_argument("--n", type=int, default=40_000)
    p.add_argument("--r", type=int, default=32)
    args = p.parse_args(argv)
    rows = run(n=args.n, r=args.r, quick=args.smoke)
    # the planner's promise: the band partition stays balanced at every w
    assert all(row["imbalance"] <= 1.2 for row in rows), rows
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
