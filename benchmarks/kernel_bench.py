"""Kernel micro-benchmarks: the pair-similarity hot spot.

On this CPU container the Pallas kernels run in interpret mode (Python —
correctness only, not speed), so throughput is measured on the XLA path
and the kernel tiling parameters are reported structurally (VMEM bytes
per grid step, MXU-aligned tile dims). Real-TPU wall clocks belong on
real TPUs; the roofline harness (launch/roofline.py) covers the compiled
side."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import print_table, save_rows


def _bench(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []
    sizes = [(1024, 256), (4096, 256)] if not quick else [(1024, 256)]
    for n, d in sizes:
        a = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        for bm in (128, 256):
            vmem = (bm * d + bm * d + bm * bm) * 4
            t = _bench(lambda x=a: ops.pair_scores(
                x, x, threshold=0.8, triangular=True, impl="xla"))
            pairs = n * (n - 1) / 2
            rows.append({
                "kernel": "pair_sim", "n": n, "d": d, "tile": f"{bm}x{bm}",
                "vmem_per_step_kib": vmem // 1024,
                "xla_ref_s": round(t, 4),
                "gpairs_per_s(xla)": round(pairs / t / 1e9, 3),
            })
    print_table("kernel bench — pair_sim (XLA path; Pallas = TPU target)",
                rows)
    save_rows("kernel_bench", rows)
    return rows


if __name__ == "__main__":
    run()
