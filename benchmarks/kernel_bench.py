"""Kernel micro-benchmarks: the pair-similarity hot spot.

Two suites:
  * :func:`run` — dense pair_scores tiling structure + XLA throughput
    (unchanged from the seed; Pallas wall clocks belong on real TPUs).
  * :func:`run_catalog` — the tile-catalog executor (er/executor.py)
    against the reference host path it replaced (per-reducer materialized
    pair lists + chunked ``np.einsum`` stage-1 filter), at the paper's
    Fig. 9 skew=1.0 exponential block distribution. Survivor sets are
    asserted identical; before/after throughput is recorded in
    ``BENCH_pair_sim.json`` at the repo root so later PRs have a perf
    trajectory. On a real (TPU) backend the catalog executor must win by
    >= 5x; CPU interpret/XLA numbers are recorded but not asserted.

On this CPU container the Pallas kernels run in interpret mode (Python —
correctness only, not speed), so the catalog executor times its
production CPU path (the batched-matmul XLA twin) instead.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import print_table, save_rows

_BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_pair_sim.json")

_CHUNK = 65_536


def _bench(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []
    sizes = [(1024, 256), (4096, 256)] if not quick else [(1024, 256)]
    for n, d in sizes:
        a = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        for bm in (128, 256):
            vmem = (bm * d + bm * d + bm * bm) * 4
            t = _bench(lambda x=a: ops.pair_scores(
                x, x, threshold=0.8, triangular=True, impl="xla"))
            pairs = n * (n - 1) / 2
            rows.append({
                "kernel": "pair_sim", "n": n, "d": d, "tile": f"{bm}x{bm}",
                "vmem_per_step_kib": vmem // 1024,
                "xla_ref_s": round(t, 4),
                "gpairs_per_s(xla)": round(pairs / t / 1e9, 3),
            })
    print_table("kernel bench — pair_sim (XLA path; Pallas = TPU target)",
                rows)
    save_rows("kernel_bench", rows)
    return rows


# ---------------------------------------------------------------------------
# Tile-catalog executor vs reference numpy stage-1 (Fig. 9 skew=1.0)
# ---------------------------------------------------------------------------

def _stage1_numpy(feats, plan, strategy, estart, sizes, threshold):
    """The replaced hot path: materialize each reduce task's pair list
    (triu_indices / meshgrid / closed-form inverse), filter with chunked
    paired-dot einsum. Returns the survivor pair set size + arrays."""
    from repro.core import pairs_of_range
    from repro.er.compiler import enumerate_task_pairs as _tile_pairs

    cand_a, cand_b = [], []

    def filt(ra, rb):
        for lo in range(0, ra.shape[0], _CHUNK):
            a = ra[lo:lo + _CHUNK]
            b = rb[lo:lo + _CHUNK]
            cos = np.einsum("pd,pd->p", feats[a], feats[b])
            sel = np.flatnonzero(cos >= threshold)
            cand_a.append(a[sel])
            cand_b.append(b[sel])

    if strategy == "pair_range":
        for k in range(plan.r):
            _, _, _, ra, rb = pairs_of_range(plan, k)
            filt(ra, rb)
    elif strategy == "block_split":
        for t in range(plan.task_block.shape[0]):
            ra, rb = _tile_pairs(
                int(plan.task_a_start[t]), int(plan.task_a_len[t]),
                int(plan.task_b_start[t]), int(plan.task_b_len[t]),
                bool(plan.task_triangular[t]))
            filt(ra, rb)
    else:  # basic
        for k in np.flatnonzero(sizes >= 2):
            ra, rb = _tile_pairs(int(estart[k]), int(sizes[k]), 0, 0, True)
            filt(ra, rb)
    ca = np.concatenate(cand_a) if cand_a else np.zeros(0, np.int64)
    cb = np.concatenate(cand_b) if cand_b else np.zeros(0, np.int64)
    return ca, cb


def run_catalog(quick: bool = False):
    from repro.core import (compute_bdm, plan_basic, plan_block_split,
                            plan_pair_range)
    from repro.er.blocking import exponential_block_ids
    from repro.er.executor import build_catalog, score_catalog

    n = 3_000 if quick else 8_000
    d, r, m = 256, 100, 20
    s = 1.0                          # Fig. 9's hardest skew point
    # Random unit vectors concentrate near cos=0 (sigma ~ 1/sqrt(d)); a
    # ~2.4-sigma cut keeps ~1% survivors so the before/after set-equality
    # check and the compaction cost are both exercised.
    threshold = 0.15

    rng = np.random.default_rng(7)
    bid = exponential_block_ids(n, b=100, s=s, rng=rng)
    feats = rng.standard_normal((n, d)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)

    # Blocked layout: stable sort by block id; partitions round-robin.
    order = np.argsort(bid, kind="stable")
    feats = feats[order]
    bid_sorted = bid[order]
    sizes = np.bincount(bid_sorted)
    estart = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    part = np.arange(n, dtype=np.int64) % m
    bdm = compute_bdm(bid_sorted, part, int(sizes.shape[0]), m)

    backend = jax.default_backend()
    impl = "pallas" if backend == "tpu" else "xla"
    rows = []
    strategies = ("block_split",) if quick else (
        "basic", "block_split", "pair_range")
    for strategy in strategies:
        plan = {"basic": plan_basic, "block_split": plan_block_split,
                "pair_range": plan_pair_range}[strategy](bdm, r)
        total = plan.total_pairs

        t0 = time.perf_counter()
        na, nb = _stage1_numpy(feats, plan, strategy, estart, sizes,
                               threshold)
        t_numpy = time.perf_counter() - t0

        # warm once (jit compile), then time plan-compile + execution —
        # the catalog build is part of the executor's work.
        cat = build_catalog(plan)
        score_catalog(feats, cat, threshold=threshold, impl=impl)
        t0 = time.perf_counter()
        cat = build_catalog(plan)
        ca, cb = score_catalog(feats, cat, threshold=threshold, impl=impl)
        t_catalog = time.perf_counter() - t0

        norm = {(min(a, b), max(a, b)) for a, b in zip(na.tolist(),
                                                       nb.tolist())}
        got = {(min(a, b), max(a, b)) for a, b in zip(ca.tolist(),
                                                      cb.tolist())}
        assert got == norm, (strategy, len(got), len(norm))

        speedup = t_numpy / max(t_catalog, 1e-9)
        rows.append({
            "strategy": strategy, "n": n, "pairs": int(total),
            "tiles": cat.num_tiles, "survivors": len(got),
            "numpy_s": round(t_numpy, 4), "catalog_s": round(t_catalog, 4),
            "mpairs_per_s(numpy)": round(total / t_numpy / 1e6, 1),
            "mpairs_per_s(catalog)": round(total / t_catalog / 1e6, 1),
            "speedup": round(speedup, 2),
        })
    print_table(f"tile-catalog executor vs numpy stage-1 "
                f"(Fig. 9 skew={s}, backend={backend}, impl={impl})", rows)
    save_rows("kernel_bench_catalog", rows)
    if not quick:  # smoke runs must not clobber the full-run trajectory
        with open(_BENCH_JSON, "w") as f:
            json.dump({"suite": "catalog_executor_stage1_vs_numpy",
                       "backend": backend, "impl": impl, "skew": s,
                       "updated": time.strftime("%Y-%m-%d %H:%M:%S"),
                       "rows": rows}, f, indent=1)
    if backend == "tpu":  # CPU interpret/XLA exempt per acceptance criteria
        worst = min(row["speedup"] for row in rows)
        assert worst >= 5.0, f"catalog executor speedup {worst} < 5x"
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="reduced sizes (CI-speed)")
    args = p.parse_args()
    run(quick=args.smoke)
    run_catalog(quick=args.smoke)
