"""Run every paper-figure benchmark: ``python -m benchmarks.run [--quick]``.

One module per paper table/figure (Fig. 8-14) + kernel benches. The
dry-run/roofline tables (deliverables e and g) are produced separately
by ``python -m repro.launch.dryrun`` because they pin XLA_FLAGS at
process start; their latest outputs are summarized here if present.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced dataset sizes (CI-speed)")
    p.add_argument("--only", default=None,
                   help="comma-separated subset: fig8,fig9,...,kernels")
    args = p.parse_args(argv)

    from . import (chaos_bench, fig8_datasets, fig9_skew,
                   fig10_reduce_tasks, fig11_sorted, fig12_map_output,
                   fig13_scaling, fig_sn_window, kernel_bench,
                   mesh_bench, schedule_bench, serve_bench, steal_bench,
                   tune_bench)

    suites = {
        "fig8": lambda: fig8_datasets.run(quick=args.quick),
        "fig9": lambda: fig9_skew.run(quick=args.quick),
        "fig10": lambda: fig10_reduce_tasks.run(quick=args.quick),
        "fig11": lambda: fig11_sorted.run(quick=args.quick),
        "fig12": lambda: fig12_map_output.run(quick=args.quick),
        "fig13": lambda: fig13_scaling.run(quick=args.quick),
        "sn_window": lambda: fig_sn_window.run(quick=args.quick),
        "kernels": lambda: kernel_bench.run(quick=args.quick),
        "schedule": lambda: schedule_bench.run(quick=args.quick),
        "serve": lambda: serve_bench.run(quick=args.quick),
        "mesh": lambda: mesh_bench.run(quick=args.quick),
        "chaos": lambda: chaos_bench.run(quick=args.quick),
        "steal": lambda: steal_bench.run(quick=args.quick),
        "tune": lambda: tune_bench.run(quick=args.quick),
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    t0 = time.time()
    for name, fn in suites.items():
        if name not in only:
            continue
        print(f"\n######## {name} ########", flush=True)
        fn()
    # summarize dry-run outputs if present
    for mesh in ("16x16", "2x16x16"):
        path = os.path.join(os.path.dirname(__file__), "out",
                            f"dryrun_{mesh}.json")
        if os.path.exists(path):
            with open(path) as f:
                rows = json.load(f)
            ok = sum(1 for r in rows if r.get("status") == "ok")
            skip = sum(1 for r in rows if r.get("status") == "skip")
            fail = sum(1 for r in rows if r.get("status") == "fail")
            print(f"\ndry-run {mesh}: {ok} ok / {skip} skip / {fail} fail "
                  f"({path})")
    print(f"\ntotal bench time: {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
