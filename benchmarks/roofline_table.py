"""Render the §Dry-run / §Roofline markdown tables from the dry-run JSON
outputs: ``python -m benchmarks.roofline_table [--mesh 16x16]``."""
from __future__ import annotations

import argparse
import json
import os


def _fmt_s(v):
    if v is None:
        return "-"
    if v >= 1:
        return f"{v:.2f}s"
    return f"{v * 1e3:.1f}ms"


def render(mesh: str = "16x16") -> str:
    path = os.path.join(os.path.dirname(__file__), "out",
                        f"dryrun_{mesh}.json")
    with open(path) as f:
        rows = json.load(f)
    rows.sort(key=lambda r: (r.get("arch", ""), r.get("shape", "")))
    out = [
        f"### Roofline — mesh {mesh} "
        f"({rows[0].get('chips', '?') if rows else '?'} chips)",
        "",
        "| arch | shape | compute | memory | collective | bottleneck | "
        "step | useful-FLOPs | MFU | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"FAIL | — | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"{r['bottleneck']} | {_fmt_s(r['step_s'])} | "
            f"{r['useful_flops_frac']:.2f} | {r['mfu']:.3f} | "
            f"{r.get('temp_bytes_gib', 0):.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", default="16x16")
    print(render(p.parse_args().mesh))
