"""Fig. 12 — map output volume (kv-pairs emitted) vs r.

Exact counts from the plans: Basic emits every entity once; BlockSplit
replicates split-block entities once per non-empty partition
(step-function in r: more reducers → more blocks split, bounded by m);
PairRange's replication grows ~linearly with r. On the TPU mapping this
is the collective-volume term (bytes over ICI) — reported here both as
kv-pairs (paper units) and as gathered feature bytes."""
from __future__ import annotations

import numpy as np

from repro.core import compute_bdm, entity_indices, plan_basic, plan_block_split, plan_pair_range
from repro.core.pair_range import map_output_size
from repro.er.blocking import prefix_block_ids
from repro.er.datasets import make_products

from .common import print_table, save_rows

FEATURE_BYTES = 256 * 4 + 64 + 4   # ngram f32 + codes + length per entity


def run(n: int = 20_000, quick: bool = False):
    if quick:
        n = 8_000
    ds = make_products(n)
    bid, _ = prefix_block_ids(ds.titles, ds.prefix_len)
    m = 20
    part = np.minimum(np.arange(ds.n) * m // ds.n, m - 1)
    bdm = compute_bdm(bid, part, int(bid.max()) + 1, m)
    rows = []
    for r in (20, 40, 80, 120, 160):
        basic = plan_basic(bdm, r)
        bsplit = plan_block_split(bdm, r)
        prange = plan_pair_range(bdm, r)
        for name, size in (("basic", basic.map_output_size()),
                           ("block_split", bsplit.map_output_size()),
                           ("pair_range", map_output_size(prange))):
            rows.append({
                "r": r, "strategy": name, "map_kv_pairs": int(size),
                "replication": round(size / ds.n, 3),
                "ici_mbytes": round(size * FEATURE_BYTES / 1e6, 1),
            })
    print_table("Fig. 12 — map output volume", rows)
    save_rows("fig12_map_output", rows)
    return rows


if __name__ == "__main__":
    run()
