"""Chaos drill for the fault-tolerant runtime (DESIGN.md §Fault
tolerance): seeded failure scripts — device kills, revives, stragglers,
transient errors, corrupted shards, up to n_dev − 1 concurrent fatal
devices — injected into an 8-device supervised run over the paper's
Fig. 9 robustness workload (b = 100 blocks, |Φ_k| ∝ e^{−s·k}, s = 1.0,
the skew that collapses Basic onto one reducer).

Two drills, both asserted (the CI bar):

  * **executor** — ``execute_supervised`` under every scripted scenario
    returns EXACTLY the failure-free (quiet) survivor set, coverage 1.0
    after recovery, retries within the configured bound; recovery
    latency, rounds, and recovered-tile counts are recorded per script.
  * **service** — an :class:`ERService` with supervised execution serves
    identical traffic twice, quiet vs chaos (kills + a later revive);
    the chaos stream must match the quiet stream batch for batch, with
    the circuit breaker evicting the dead device and re-admitting it
    after the revive lands.

Rows land in ``benchmarks/out/chaos_bench.json``.

    PYTHONPATH=src python -m benchmarks.chaos_bench [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import (compute_bdm, plan_basic, plan_block_split,
                        plan_pair_range)
from repro.er import ERService, ServiceConfig, make_products
from repro.er.blocking import exponential_block_ids
from repro.er.compiler import (FaultEvent, FaultInjector, FaultScript,
                               execute, execute_supervised, lower,
                               plan_to_job)

from .common import print_table, save_rows, timer

N_DEV = 8
THRESH = 0.4
STRATEGIES = {"basic": plan_basic, "block_split": plan_block_split,
              "pair_range": plan_pair_range}


def _workload(n: int, r: int):
    """Fig. 9 robustness blocking at s = 1.0, lowered per strategy."""
    rng = np.random.default_rng(9)
    bid = exponential_block_ids(n, b=100, s=1.0, rng=rng)
    bdm = compute_bdm(bid, np.zeros(n, np.int64), int(bid.max()) + 1, 1)
    feats = rng.normal(size=(n, 64)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    cats = {name: lower(plan_to_job(mk(bdm, r)), 64, 64)
            for name, mk in STRATEGIES.items()}
    return cats, feats


def _pairs(ra, rb):
    return set(zip(ra.tolist(), rb.tolist()))


def executor_drill(n: int, r: int, n_scripts: int):
    cats, feats = _workload(n, r)
    rows = []
    for strat, cat in cats.items():
        want = _pairs(*execute(cat, feats, threshold=THRESH))
        for seed in range(n_scripts):
            n_events = 4 + seed
            script = FaultScript.random(seed, N_DEV, n_events,
                                        max_step=24, straggle_delay=1e6,
                                        allow_revive=True)
            max_retries = n_events + 2
            with timer() as t:
                ra, rb, rep = execute_supervised(
                    cat, feats, threshold=THRESH, n_dev=N_DEV,
                    shard_deadline=120.0, max_retries=max_retries,
                    backoff=0.0, injector=FaultInjector(script, seed=seed))
            assert _pairs(ra, rb) == want, (strat, seed)
            assert rep.coverage == 1.0 and rep.lost_tiles == 0, (strat, seed)
            assert rep.retries <= max_retries, (strat, seed)
            statuses = [rec.status for rec in rep.records]
            rows.append({
                "drill": "executor", "strategy": strat, "seed": seed,
                "events": len(script.events), "rounds": rep.rounds,
                "retries": rep.retries,
                "recovered_tiles": rep.recovered_tiles,
                "failed_shards": sum(s != "ok" for s in statuses),
                "coverage": rep.coverage,
                "recovery_s": round(t.seconds, 4),
                # real wall seconds vs injected virtual delay, split per
                # record — chaos scripts must not poison latency stats
                "shard_real_s": round(sum(rec.elapsed
                                          for rec in rep.records), 4),
                "shard_injected_s": round(sum(rec.injected_delay
                                              for rec in rep.records), 4),
                "exact": True,
            })
    return rows


def service_drill(n_corpus: int, n_batches: int, batch: int):
    ds = make_products(n_corpus + n_batches * batch, seed=3)
    corpus = ds.titles[:n_corpus]
    batches = [ds.titles[n_corpus + i * batch:n_corpus + (i + 1) * batch]
               for i in range(n_batches)]
    cfg = dict(feature_dim=128, max_len=48, r=8, m=4,
               query_buckets=(batch,), tile_chunk=64)

    quiet = ERService(corpus, ServiceConfig(**cfg))
    want = [set(quiet.match(b)) for b in batches]

    svc = ERService(corpus, ServiceConfig(
        exec_devices=N_DEV, backoff_s=0.0, breaker_threshold=1,
        breaker_cooldown_s=0.0, **cfg))
    svc.set_fault_injector(FaultInjector(FaultScript(events=(
        FaultEvent("kill", 2, 0),
        FaultEvent("kill", 5, 3),
        FaultEvent("corrupt", 1, 5),
        FaultEvent("revive", 2, 30),
        FaultEvent("revive", 5, 30)), n_dev=N_DEV)))
    rows = []
    for i, (b, w) in enumerate(zip(batches, want)):
        with timer() as t:
            resp = svc.match(b)
        assert set(resp) == w, f"batch {i} diverged under chaos"
        assert resp.coverage == 1.0 and not resp.degraded, i
        rows.append({
            "drill": "service", "batch": i, "queries": len(b),
            "matches": len(resp), "attempts": resp.attempts,
            "recovered_tiles": resp.recovered_tiles,
            "coverage": resp.coverage, "seconds": round(t.seconds, 4),
            "exact": True,
        })
    s = svc.stats
    assert s["degraded"] == 0
    assert s["breaker_evictions"] >= 1, "kills never tripped the breaker"
    assert s["breaker_readmissions"] >= 1, "revive was never probed back"
    rows.append({
        "drill": "service", "batch": "total", "queries": s["queries"],
        "matches": s["matches"], "attempts": s["retries"],
        "recovered_tiles": s["recovered_tiles"], "coverage": 1.0,
        "seconds": round(s["seconds"], 4), "exact": True,
        "evictions": s["breaker_evictions"],
        "readmissions": s["breaker_readmissions"],
    })
    return rows


def run(n: int = 4_000, r: int = 32, n_scripts: int = 6,
        n_corpus: int = 300, n_batches: int = 12, batch: int = 16,
        quick: bool = False):
    if quick:
        n, n_scripts = 1_200, 3
        n_corpus, n_batches = 200, 6
    rows = executor_drill(n, r, n_scripts)
    rows += service_drill(n_corpus, n_batches, batch)
    exec_rows = [row for row in rows if row["drill"] == "executor"]
    print_table(
        f"chaos_bench — executor drill (n={n}, s=1.0, n_dev={N_DEV}, "
        f"{n_scripts} scripts × {len(STRATEGIES)} strategies)", exec_rows,
        cols=["strategy", "seed", "events", "rounds", "retries",
              "recovered_tiles", "failed_shards", "coverage",
              "recovery_s", "shard_real_s", "shard_injected_s", "exact"])
    svc_rows = [row for row in rows if row["drill"] == "service"]
    print_table("chaos_bench — service drill (kills + revive, breaker)",
                svc_rows,
                cols=["batch", "queries", "matches", "attempts",
                      "recovered_tiles", "coverage", "seconds", "exact"])
    path = save_rows("chaos_bench", rows)
    worst = max(row["retries"] for row in exec_rows)
    print(f"\nall scripts recovered to the exact quiet match set "
          f"(coverage 1.0, worst retries {worst}) — {path}")
    return rows


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv)
