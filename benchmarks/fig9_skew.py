"""Fig. 9 — robustness against data skew.

Blocking is replaced by a controlled exponential distribution over b=100
blocks, |Φ_k| ∝ e^{−s·k}, s ∈ [0, 1] (the paper's setup, n=10 nodes,
m=20, r=100). Reported per strategy: average execution time per 10⁴
pairs — measured (vectorized single-host matching, so measured time ≈
total work) and modeled parallel makespan per 10⁴ pairs (max reducer
load × measured cost/pair + BDM overhead).

Expected reproduction of the paper's finding: Basic degrades by an
order of magnitude as s grows (for s=1 the paper measures 12× vs the
balanced strategies); BlockSplit/PairRange stay flat.
"""
from __future__ import annotations

import numpy as np

from repro.er import ERConfig, make_products, run_er
from repro.er.blocking import exponential_block_ids

from .common import print_table, save_rows


def run(n: int = 20_000, quick: bool = False):
    if quick:
        n = 8_000
    ds = make_products(n)
    rng = np.random.default_rng(7)
    rows = []
    for s in (0.0, 0.25, 0.5, 0.75, 1.0):
        block_ids = exponential_block_ids(ds.n, b=100, s=s, rng=rng)
        for strat in ("basic", "block_split", "pair_range"):
            cfg = ERConfig(strategy=strat, r=100, m=20)
            res = run_er(ds.titles, cfg, block_ids=block_ids)
            total_pairs = res.total_pairs
            work_s = float(res.reducer_seconds.sum())
            cost_per_pair = work_s / max(total_pairs, 1)
            modeled = (res.reducer_pairs.max() * cost_per_pair
                       + res.bdm_seconds)
            rows.append({
                "s": s, "strategy": strat, "pairs": total_pairs,
                "max_load": int(res.reducer_pairs.max()),
                "mean_load": float(res.reducer_pairs.mean()),
                "imbalance": round(float(res.reducer_pairs.max()
                                         / max(res.reducer_pairs.mean(), 1)), 2),
                "modeled_makespan_s": round(modeled, 4),
                "ms_per_1e4_pairs": round(1e4 * modeled / max(total_pairs, 1) * 1e3, 4),
            })
    print_table("Fig. 9 — skew robustness (modeled makespan per 10^4 pairs)",
                rows)
    save_rows("fig9_skew", rows)
    # the paper's headline: Basic at s=1 is >10× the balanced strategies
    at1 = {r["strategy"]: r["modeled_makespan_s"] for r in rows if r["s"] == 1.0}
    ratio = at1["basic"] / max(min(at1["block_split"], at1["pair_range"]), 1e-9)
    print(f"Basic/balanced makespan ratio at s=1.0: {ratio:.1f}× "
          f"(paper: >12×)")
    return rows


if __name__ == "__main__":
    run()
