"""Cost-LPT vs round-robin tile scheduling at Fig. 9 skews.

Blocking follows the paper's robustness setup — b = 100 blocks with
|Φ_k| ∝ e^{−s·k}, s ∈ {0.0, 0.5, 1.0} — and every strategy's plan is
lowered to a tile catalog by the unified compiler. The two scheduling
policies are then compared on identical catalogs:

  * ``round_robin`` — the pre-compiler behavior: the plan's own reducer
    attribution, reducers → devices round-robin;
  * ``cost_lpt`` — tiles → reducers → devices by greedy LPT over the
    exact per-tile live-pair counts (``compiler.tile_costs``).

Reported per (skew, strategy): device imbalance (max/mean load over the
paper's balance metric, live pairs), modeled device makespan in pairs,
and the scheduling wall time itself. Asserted (the CI bar): cost-LPT is
never worse than round-robin beyond one tile of quantization, and at
s = 1.0 it is STRICTLY better on the skew-collapsing Basic strategy —
the paper's headline case, where hash partitioning pins the dominant
block to one reducer.

    PYTHONPATH=src python -m benchmarks.schedule_bench [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import (compute_bdm, plan_basic, plan_block_split,
                        plan_pair_range)
from repro.er.blocking import exponential_block_ids
from repro.er.compiler import lower, plan_to_job, schedule_tiles

from .common import print_table, save_rows, timer

SKEWS = (0.0, 0.5, 1.0)
STRATEGIES = (("basic", plan_basic), ("block_split", plan_block_split),
              ("pair_range", plan_pair_range))


def run(n: int = 20_000, b: int = 100, m: int = 20, r: int = 32,
        n_dev: int = 8, quick: bool = False):
    if quick:
        n = 6_000
    rng = np.random.default_rng(7)
    part = np.minimum(np.arange(n, dtype=np.int64) * m // n, m - 1)
    rows = []
    for s in SKEWS:
        bid = exponential_block_ids(n, b=b, s=s, rng=rng)
        bdm = compute_bdm(bid, part, int(bid.max()) + 1, m)
        for strat, mk_plan in STRATEGIES:
            plan = mk_plan(bdm, r)
            catalog = lower(plan_to_job(plan))
            row = {"s": s, "strategy": strat, "pairs": plan.total_pairs,
                   "tiles": catalog.num_tiles}
            quantum = 0
            for key, policy in (("rr", "round_robin"), ("lpt", "cost_lpt")):
                with timer() as t:
                    sched = schedule_tiles(catalog, n_dev=n_dev,
                                           policy=policy)
                stats = sched.stats()["device"]
                row[f"{key}_imbalance"] = round(stats["imbalance"], 3)
                row[f"{key}_makespan_pairs"] = int(stats["max"])
                row[f"{key}_sched_ms"] = round(t.seconds * 1e3, 2)
                quantum = max(quantum, int(sched.tile_cost.max())
                              if sched.tile_cost.size else 0)
            row["quantum"] = quantum
            row["win"] = round(row["rr_makespan_pairs"]
                               / max(row["lpt_makespan_pairs"], 1), 2)
            rows.append(row)
    print_table(f"schedule_bench — cost-LPT vs round-robin device loads "
                f"(n={n}, b={b}, r={r}, n_dev={n_dev})", rows)
    save_rows("schedule_bench", rows)

    # CI bars: never worse than one tile quantum; strictly better where
    # the paper says balancing matters (Basic at s = 1.0).
    for row in rows:
        assert (row["lpt_makespan_pairs"]
                <= row["rr_makespan_pairs"] + row["quantum"]), row
    headline = [row for row in rows
                if row["s"] == 1.0 and row["strategy"] == "basic"]
    for row in headline:
        assert row["lpt_imbalance"] < row["rr_imbalance"], row
        assert row["lpt_makespan_pairs"] < row["rr_makespan_pairs"], row
        print(f"Basic @ s=1.0: device imbalance {row['rr_imbalance']} → "
              f"{row['lpt_imbalance']} ({row['win']}× makespan win)")
    return rows


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv)
