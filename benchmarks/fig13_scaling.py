"""Figs. 13/14 — scalability with the number of nodes n (DS1 and DS2).

Paper setup: m = 2n map tasks, r = 10n reduce tasks, n ∈ [1, 100].
Makespans are modeled from EXACT plan load distributions (the paper's
own balance metric) with a measured cost-per-pair:

    makespan(n) = max_k(load_k) · cost_per_pair / cores_per_node(2)
                  + bdm_overhead(n)

DS2 runs plan-math at full 1.39M-entity scale (5.6·10⁹ pairs — loads are
exact; no pair is materialized); cost_per_pair is measured on a DS1-
scale sample. Expected findings: Basic flatlines past 2 nodes; the
balanced strategies scale near-linearly until per-reducer work gets too
small (DS1 ~10 nodes, DS2 ~40 nodes); BlockSplit beats PairRange on
small datasets at large n (replication overhead), PairRange wins on DS2.

Every strategy row also carries the EXACT per-device interconnect bytes
each stage-1 gather policy would move at that node count
(``compiler.comms.comms_volume`` over the strategy's own lowered tile
catalog): the flat all-gather ships (n − 1) strips regardless of
locality, while ring/hierarchical shrink with the tiles' strip spans —
O(n_rows) vs O(n_rows/n · hops) per device, out to 100 simulated nodes.
A measured leg re-runs the small-n points on real simulated device
meshes (subprocess; ``run_er(mesh=...)`` with flat vs ring comms) and
reports wall time plus the executor's own byte counters, checking
match-set equality against the single-host run.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import compute_bdm, plan_basic, plan_block_split, plan_pair_range
from repro.er import ERConfig, run_er
from repro.er.blocking import prefix_block_ids
from repro.er.compiler import comms_volume, lower, plan_to_job
from repro.er.datasets import make_products, make_publications

from .common import print_table, save_rows

NODES = (1, 2, 5, 10, 20, 40, 100)
MEASURED_NODES = (2, 4, 8)

_MARK = "FIG13_MEASURED "

MEASURED_SCRIPT = textwrap.dedent("""
    import json, os, sys, time
    n_dev, n_corpus = int(sys.argv[1]), int(sys.argv[2])
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + str(n_dev))
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from dataclasses import replace
    from repro.er import ERConfig, run_er
    from repro.er.datasets import make_products
    from repro.er.compiler.execute import stage1_stats
    from repro.sharding import make_er_mesh

    cfg = ERConfig(strategy="pair_range", r=10 * n_dev, m=2 * n_dev,
                   feature_dim=128, max_len=48)
    titles = make_products(n_corpus, seed=1).titles
    host = run_er(titles, cfg)
    mesh = make_er_mesh(n_dev)
    rows = []
    for comms in ("flat", "ring"):
        before = dict(stage1_stats["interconnect"])
        t0 = time.perf_counter()
        res = run_er(titles, replace(cfg, comms=comms), mesh=mesh)
        wall = time.perf_counter() - t0
        after = stage1_stats["interconnect"]
        rows.append({
            "policy": comms, "equal": res.matches == host.matches,
            "wall_s": round(wall, 2),
            "flat_gather_B": after["flat_bytes"] - before["flat_bytes"],
            "ring_B": after["ring_bytes"] - before["ring_bytes"],
        })
    print("FIG13_MEASURED " + json.dumps(rows))
""")


def _measure_cost_per_pair(n_sample: int = 8_000) -> float:
    ds = make_products(n_sample)
    res = run_er(ds.titles, ERConfig(strategy="pair_range", r=16, m=8))
    return float(res.reducer_seconds.sum()) / max(res.total_pairs, 1)


def _bdm_overhead(n_entities: int, n_nodes: int) -> float:
    # one counting pass over the entities, spread over nodes + fixed job cost
    return 2e-7 * n_entities / n_nodes + 1.0


def _measured_leg(quick: bool) -> list:
    rows = []
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    nodes = MEASURED_NODES[:2] if quick else MEASURED_NODES
    n_corpus = 1500 if quick else 3000
    for n_dev in nodes:
        proc = subprocess.run(
            [sys.executable, "-c", MEASURED_SCRIPT,
             str(n_dev), str(n_corpus)],
            env=env, capture_output=True, text=True, timeout=1800,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        if proc.returncode != 0:
            raise RuntimeError(f"measured leg n={n_dev} failed:\n"
                               + proc.stdout + proc.stderr)
        for line in proc.stdout.splitlines():
            if line.startswith(_MARK):
                for r in json.loads(line[len(_MARK):]):
                    assert r.pop("equal"), \
                        f"measured mesh run diverged at n={n_dev}: {r}"
                    rows.append({"dataset": "DS1-measured", "nodes": n_dev,
                                 "strategy": f"pair_range/{r['policy']}",
                                 "makespan_s": r["wall_s"],
                                 "flat_gather_B": r["flat_gather_B"],
                                 "ring_B": r["ring_B"]})
    return rows


def run(ds1_n: int = 114_000, ds2_n: int = 1_390_000, quick: bool = False):
    if quick:
        ds1_n, ds2_n = 20_000, 60_000
    cpp = _measure_cost_per_pair()
    rows = []
    for make, nn, tag in ((make_products, ds1_n, "DS1"),
                          (make_publications, ds2_n, "DS2")):
        ds = make(nn)
        bid, _ = prefix_block_ids(ds.titles, ds.prefix_len)
        n_ent = ds.n
        for n in NODES:
            m, r = 2 * n, 10 * n
            part = np.minimum(np.arange(n_ent) * m // n_ent, m - 1)
            bdm = compute_bdm(bid, part, int(bid.max()) + 1, m)
            plans = {
                "basic": plan_basic(bdm, r),
                "block_split": plan_block_split(bdm, r),
                "pair_range": plan_pair_range(bdm, r),
            }
            for strat, plan in plans.items():
                loads = plan.reducer_pairs
                # r=10n reducers over n nodes with 2 cores: each core runs
                # 5 reducers; node time = its reducers' load sum — use the
                # round-robin node assignment of er.distributed.
                node_of = np.arange(r) % (2 * n)
                core_loads = np.bincount(node_of, weights=loads,
                                         minlength=2 * n)
                makespan = core_loads.max() * cpp + _bdm_overhead(n_ent, n)
                # Exact per-device gather bytes each comms policy would
                # move for THIS strategy's tile catalog at n shards.
                vol = comms_volume(lower(plan_to_job(plan), 128, 128),
                                   n_ent, n, feature_dim=128)
                rows.append({
                    "dataset": tag, "nodes": n, "strategy": strat,
                    "max_core_load": int(core_loads.max()),
                    "makespan_s": round(float(makespan), 2),
                    "flat_gather_B": vol["flat_gather"],
                    "ring_B": vol["ring"],
                    "hier_B": vol["hier_intra"] + vol["hier_inter"],
                    "ring_hops": vol["ring_hops"],
                })
    # speedups relative to n=1
    for tag in ("DS1", "DS2"):
        for strat in ("basic", "block_split", "pair_range"):
            sel = [r for r in rows
                   if r["dataset"] == tag and r["strategy"] == strat]
            base = sel[0]["makespan_s"]
            for r_ in sel:
                r_["speedup"] = round(base / r_["makespan_s"], 2)
    rows.extend(_measured_leg(quick))
    print_table("Figs. 13/14 — node scalability (modeled + measured)", rows)
    save_rows("fig13_scaling", rows)
    return rows


if __name__ == "__main__":
    run()
