"""Figs. 13/14 — scalability with the number of nodes n (DS1 and DS2).

Paper setup: m = 2n map tasks, r = 10n reduce tasks, n ∈ [1, 100].
Makespans are modeled from EXACT plan load distributions (the paper's
own balance metric) with a measured cost-per-pair:

    makespan(n) = max_k(load_k) · cost_per_pair / cores_per_node(2)
                  + bdm_overhead(n)

DS2 runs plan-math at full 1.39M-entity scale (5.6·10⁹ pairs — loads are
exact; no pair is materialized); cost_per_pair is measured on a DS1-
scale sample. Expected findings: Basic flatlines past 2 nodes; the
balanced strategies scale near-linearly until per-reducer work gets too
small (DS1 ~10 nodes, DS2 ~40 nodes); BlockSplit beats PairRange on
small datasets at large n (replication overhead), PairRange wins on DS2.
"""
from __future__ import annotations

import numpy as np

from repro.core import compute_bdm, plan_basic, plan_block_split, plan_pair_range
from repro.er import ERConfig, run_er
from repro.er.blocking import prefix_block_ids
from repro.er.datasets import make_products, make_publications

from .common import print_table, save_rows

NODES = (1, 2, 5, 10, 20, 40, 100)


def _measure_cost_per_pair(n_sample: int = 8_000) -> float:
    ds = make_products(n_sample)
    res = run_er(ds.titles, ERConfig(strategy="pair_range", r=16, m=8))
    return float(res.reducer_seconds.sum()) / max(res.total_pairs, 1)


def _bdm_overhead(n_entities: int, n_nodes: int) -> float:
    # one counting pass over the entities, spread over nodes + fixed job cost
    return 2e-7 * n_entities / n_nodes + 1.0


def run(ds1_n: int = 114_000, ds2_n: int = 1_390_000, quick: bool = False):
    if quick:
        ds1_n, ds2_n = 20_000, 60_000
    cpp = _measure_cost_per_pair()
    rows = []
    for make, nn, tag in ((make_products, ds1_n, "DS1"),
                          (make_publications, ds2_n, "DS2")):
        ds = make(nn)
        bid, _ = prefix_block_ids(ds.titles, ds.prefix_len)
        n_ent = ds.n
        for n in NODES:
            m, r = 2 * n, 10 * n
            part = np.minimum(np.arange(n_ent) * m // n_ent, m - 1)
            bdm = compute_bdm(bid, part, int(bid.max()) + 1, m)
            plans = {
                "basic": plan_basic(bdm, r).reducer_pairs,
                "block_split": plan_block_split(bdm, r).reducer_pairs,
                "pair_range": plan_pair_range(bdm, r).reducer_pairs,
            }
            for strat, loads in plans.items():
                # r=10n reducers over n nodes with 2 cores: each core runs
                # 5 reducers; node time = its reducers' load sum — use the
                # round-robin node assignment of er.distributed.
                node_of = np.arange(r) % (2 * n)
                core_loads = np.bincount(node_of, weights=loads,
                                         minlength=2 * n)
                makespan = core_loads.max() * cpp + _bdm_overhead(n_ent, n)
                rows.append({
                    "dataset": tag, "nodes": n, "strategy": strat,
                    "max_core_load": int(core_loads.max()),
                    "makespan_s": round(float(makespan), 2),
                })
    # speedups relative to n=1
    for tag in ("DS1", "DS2"):
        for strat in ("basic", "block_split", "pair_range"):
            sel = [r for r in rows
                   if r["dataset"] == tag and r["strategy"] == strat]
            base = sel[0]["makespan_s"]
            for r_ in sel:
                r_["speedup"] = round(base / r_["makespan_s"], 2)
    print_table("Figs. 13/14 — node scalability (modeled)", rows)
    save_rows("fig13_scaling", rows)
    return rows


if __name__ == "__main__":
    run()
