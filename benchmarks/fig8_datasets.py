"""Fig. 8 — dataset statistics: blocks, pairs, largest-block shares.

The generators are calibrated to the paper's skew shares (DS1 largest
block ≈ 71% of pairs; DS2 ≈ 4% entities / 26% pairs); block counts float
(the printed DS1 row is Cauchy-Schwarz-infeasible — see module docstring
of er/datasets.py)."""
from __future__ import annotations

import numpy as np

from repro.er.blocking import prefix_block_ids
from repro.er.datasets import make_products, make_publications

from .common import print_table, save_rows, timer


def run(ds1_n: int = 114_000, ds2_n: int = 139_000, quick: bool = False):
    if quick:
        ds1_n, ds2_n = 20_000, 30_000
    rows = []
    for ds in (make_products(ds1_n), make_publications(ds2_n)):
        with timer() as t:
            bid, _ = prefix_block_ids(ds.titles, ds.prefix_len)
        sizes = np.bincount(bid[bid >= 0])
        pairs = sizes.astype(np.int64) * (sizes.astype(np.int64) - 1) // 2
        rows.append({
            "dataset": ds.name,
            "entities": ds.n,
            "blocks": int(len(sizes)),
            "pairs": int(pairs.sum()),
            "largest_block_entities_pct": round(100 * sizes.max() / ds.n, 2),
            "largest_block_pairs_pct": round(100 * pairs.max() / pairs.sum(), 2),
            "true_dups": len(ds.true_pairs),
            "blocking_s": round(t.seconds, 3),
        })
    print_table("Fig. 8 — dataset statistics", rows)
    save_rows("fig8_datasets", rows)
    return rows


if __name__ == "__main__":
    run()
