"""Fig. 11 — sorted input data.

Sorting DS1 by title groups each large block into one map partition, so
BlockSplit's partition-based sub-blocks collapse (a block living in one
partition yields a single sub-block = no split) and its runtime degrades
(paper: +80%); PairRange is partition-independent (paper: +13%)."""
from __future__ import annotations

from repro.er import ERConfig, make_products, run_er

from .common import print_table, save_rows


def run(n: int = 20_000, quick: bool = False):
    if quick:
        n = 8_000
    ds = make_products(n)
    variants = {
        "unsorted": ds.titles,
        "sorted": sorted(ds.titles),
    }
    rows = []
    for order, titles in variants.items():
        for strat in ("block_split", "pair_range"):
            res = run_er(titles, ERConfig(strategy=strat, r=100, m=20))
            cpp = float(res.reducer_seconds.sum()) / max(res.total_pairs, 1)
            modeled = res.reducer_pairs.max() * cpp + res.bdm_seconds
            rows.append({
                "strategy": strat, "input": order,
                "max_load": int(res.reducer_pairs.max()),
                "imbalance": round(float(res.reducer_pairs.max()
                                         / max(res.reducer_pairs.mean(), 1)), 2),
                "modeled_makespan_s": round(modeled, 4),
            })
    print_table("Fig. 11 — sorted vs unsorted input", rows)
    for strat in ("block_split", "pair_range"):
        u = next(r for r in rows if r["strategy"] == strat and r["input"] == "unsorted")
        s = next(r for r in rows if r["strategy"] == strat and r["input"] == "sorted")
        pct = 100 * (s["modeled_makespan_s"] / max(u["modeled_makespan_s"], 1e-9) - 1)
        print(f"{strat}: sorted-input degradation {pct:+.0f}% "
              f"(paper: {'+80%' if strat == 'block_split' else '+13%'})")
    save_rows("fig11_sorted", rows)
    return rows


if __name__ == "__main__":
    run()
