"""Fig. 10 — influence of the number of reduce tasks r (DS1, n=10 nodes,
m=20). The paper's findings to reproduce: Basic cannot exploit r (its
makespan is pinned to the largest block, with peaks when several large
blocks hash to one reducer); BlockSplit is stable; PairRange gains most
with large r (and wins by ~7% at r=160)."""
from __future__ import annotations

import numpy as np

from repro.er import ERConfig, make_products, run_er

from .common import print_table, save_rows


def run(n: int = 20_000, quick: bool = False):
    if quick:
        n = 8_000
    ds = make_products(n)
    rows = []
    cost_cache = {}
    for r in (20, 40, 80, 120, 160):
        for strat in ("basic", "block_split", "pair_range"):
            res = run_er(ds.titles, ERConfig(strategy=strat, r=r, m=20))
            work_s = float(res.reducer_seconds.sum())
            cpp = work_s / max(res.total_pairs, 1)
            cost_cache.setdefault(strat, cpp)
            modeled = res.reducer_pairs.max() * cpp + res.bdm_seconds
            rows.append({
                "r": r, "strategy": strat,
                "max_load": int(res.reducer_pairs.max()),
                "imbalance": round(float(res.reducer_pairs.max()
                                         / max(res.reducer_pairs.mean(), 1)), 2),
                "map_kv_pairs": res.map_output_size,
                "modeled_makespan_s": round(modeled, 4),
            })
    print_table("Fig. 10 — vary r (modeled makespan)", rows)
    save_rows("fig10_reduce_tasks", rows)
    b160 = [r for r in rows if r["r"] == 160]
    basic = next(r for r in b160 if r["strategy"] == "basic")
    best = min(r["modeled_makespan_s"] for r in b160
               if r["strategy"] != "basic")
    print(f"speedup of balanced vs Basic at r=160: "
          f"{basic['modeled_makespan_s'] / max(best, 1e-9):.1f}× (paper: 6×)")
    return rows


if __name__ == "__main__":
    run()
