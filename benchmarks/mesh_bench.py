"""2-D mesh scale-out bench: comms-policy parity + interconnect bytes.

Runs the full ``run_er`` pipeline on simulated device meshes (each leg
is a subprocess, so the device count is pinned before jax initializes)
and compares the three stage-1 gather policies on the ``data`` axis —
flat all-gather, ring strip pipeline, hierarchical group exchange —
plus the data×model 2-D mesh (feature columns sharded, partial tile
scores psum-combined) and the multi-hop RepSN halo executor at a
window wider than a shard.

Asserted invariants (the mesh scale-out contract, DESIGN.md §Mesh
scale-out):

  * every comms policy — and the 2-D data×model mesh — produces
    EXACTLY the single-host match set;
  * at 16 simulated devices the locality-placed ring policy receives
    >= 2x fewer gather bytes per device than the flat all-gather
    (blocked workloads bound the strip span, flat always ships
    (n_dev − 1) strips);
  * the multi-hop halo exchange (w − 1 > n / n_dev) matches the
    single-host SN pipeline and its per-hop byte schedule sums to
    exactly (w − 1) feature rows per device.

Byte counts are the executor's own exact per-device accounting
(``stage1_stats["interconnect"]``, populated per kernel launch), not a
model. Results land in ``benchmarks/out/mesh_bench.json``.

    PYTHONPATH=src python -m benchmarks.mesh_bench [--smoke]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import print_table, save_rows

_MARK = "MESH_BENCH_JSON "

SCRIPT = textwrap.dedent("""
    import json, os, sys, time
    n_data, n_model, n_corpus = map(int, sys.argv[1:4])
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + str(n_data * n_model))
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np, jax.numpy as jnp
    from dataclasses import replace
    from repro.er import ERConfig, run_er
    from repro.er.datasets import make_products
    from repro.er.blocking import sn_sort_order
    from repro.er.encode import encode_titles, ngram_features
    from repro.er.distributed import match_sn_dist, sn_replication_volume
    from repro.er.executor import verify_pairs
    from repro.er.compiler.execute import stage1_stats
    from repro.sharding import make_er_mesh

    FLOWS = ("flat_bytes", "ring_bytes", "hier_intra_bytes",
             "hier_inter_bytes", "halo_bytes", "psum_bytes")
    def snap():
        return {k: stage1_stats["interconnect"][k] for k in FLOWS}

    cfg = ERConfig(strategy="pair_range", r=32, m=8,
                   feature_dim=128, max_len=48)
    ds = make_products(n_corpus, seed=3)
    titles = ds.titles
    mesh = make_er_mesh(n_data, n_model)
    rows = []

    host = run_er(titles, cfg)
    for comms in ("flat", "ring", "hierarchical"):
        before = snap()
        t0 = time.perf_counter()
        res = run_er(titles, replace(cfg, comms=comms), mesh=mesh)
        wall = time.perf_counter() - t0
        d = {k: stage1_stats["interconnect"][k] - before[k] for k in FLOWS}
        gather = (d["flat_bytes"] + d["ring_bytes"]
                  + d["hier_intra_bytes"] + d["hier_inter_bytes"])
        rows.append({
            "leg": "comms", "policy": comms,
            "n_data": n_data, "n_model": n_model,
            "matches": len(res.matches),
            "equal": res.matches == host.matches,
            "gather_bytes_per_dev": gather,
            "psum_bytes_per_dev": d["psum_bytes"],
            "fallback": res.extra.get("comms_fallback"),
            "wall_s": round(wall, 2),
        })

    # ---- multi-hop RepSN halo: w − 1 > n / n_data ----
    n_sn = len(titles) - (len(titles) % n_data)
    sn_titles = titles[:n_sn]
    n_loc = n_sn // n_data
    W = n_loc + max(n_loc // 4, 2)        # 2 chained hops
    sn_host = run_er(sn_titles, replace(
        cfg, strategy="sorted_neighborhood", window=W, r=n_data))
    order = sn_sort_order(sn_titles)
    codes, lens = encode_titles(sn_titles, cfg.max_len)
    feats = ngram_features(codes, dim=cfg.feature_dim, lengths=lens)
    before = snap()
    ca, cb = match_sn_dist(jnp.asarray(feats[order]), W, mesh,
                           threshold=cfg.threshold - cfg.filter_margin)
    halo_recv = stage1_stats["interconnect"]["halo_bytes"] \\
        - before["halo_bytes"]
    ha, hb = verify_pairs(codes[order], lens[order], codes[order],
                          lens[order], ca, cb, cfg.threshold)
    got = set()
    for a, b in zip(ha, hb):
        ga, gb = int(order[a]), int(order[b])
        got.add((min(ga, gb), max(ga, gb)))
    per_hop = sn_replication_volume(n_sn, W, n_data, cfg.feature_dim,
                                    per_hop=True)
    rows.append({
        "leg": "halo", "policy": "multi-hop",
        "n_data": n_data, "n_model": n_model,
        "matches": len(got), "equal": got == sn_host.matches,
        "gather_bytes_per_dev": halo_recv,
        "psum_bytes_per_dev": 0,
        "hops": len(per_hop),
        "hop_bytes_ok": sum(per_hop) == (W - 1) * cfg.feature_dim * 4,
        "wall_s": None,
    })
    print("MESH_BENCH_JSON " + json.dumps(rows))
""")


def _leg(n_data: int, n_model: int, n_corpus: int) -> list:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT,
         str(n_data), str(n_model), str(n_corpus)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"mesh leg ({n_data}x{n_model}) failed:\n"
                           + proc.stdout + proc.stderr)
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError("mesh leg produced no result line:\n" + proc.stdout)


def run(quick: bool = False):
    legs = ([(16, 1, 2000), (4, 2, 640)] if quick
            else [(8, 1, 4000), (16, 1, 4000), (4, 2, 2000)])
    rows = []
    for n_data, n_model, n_corpus in legs:
        rows.extend(_leg(n_data, n_model, n_corpus))

    # ---- contract assertions ----
    for r in rows:
        assert r["equal"], f"match-set mismatch: {r}"
        assert not r.get("fallback"), f"plan degraded to flat: {r}"
    by = {(r["n_data"], r["n_model"], r["policy"]): r
          for r in rows if r["leg"] == "comms"}
    n16_flat = by[(16, 1, "flat")]["gather_bytes_per_dev"]
    n16_ring = by[(16, 1, "ring")]["gather_bytes_per_dev"]
    assert n16_flat >= 2 * max(n16_ring, 1), \
        f"ring gather {n16_ring} not >= 2x below flat {n16_flat} at 16 dev"
    for r in rows:
        if r["leg"] == "halo":
            assert r["hops"] >= 2 and r["hop_bytes_ok"], r
    for r in rows:
        if r["leg"] == "comms" and r["policy"] != "flat":
            flat = by[(r["n_data"], r["n_model"], "flat")]
            r["reduction_x"] = round(
                flat["gather_bytes_per_dev"]
                / max(r["gather_bytes_per_dev"], 1), 1)

    print_table("Mesh scale-out — gather policy parity + exact "
                "interconnect bytes/device", rows)
    save_rows("mesh_bench", rows)
    red = by[(16, 1, "ring")].get("reduction_x")
    print(f"\nOK: exact match-set equality on every leg; ring cuts gather "
          f"bytes/device {red}x vs flat at 16 devices; multi-hop halo "
          f"exact past the single-shard window")
    return rows


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv)
