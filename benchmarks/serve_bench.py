"""Serving throughput: queries/sec vs batch size on the resident index,
sequential ``match`` vs the async super-batching front-end.

Corpus blocking follows the Fig. 9 robustness setup at s = 1.0 — block
sizes |Φ_k| ∝ e^{−s·k} over b blocks, realized as distinct 3-char
prefixes so the service's own prefix blocking recovers exactly that skew
(the regime where Basic degrades >10× and the balanced two-source plans
must not). Queries are perturbed corpus samples (same generator as the
dataset ground truth) plus a few null-key entries.

Each batch size runs TWO legs over the SAME micro-batches after one
warmup:

  * **sequential** — one ``svc.match`` per micro-batch, timed per
    request (p50/p95 latency, queries/sec);
  * **batched** — the same micro-batches submitted concurrently through
    :class:`ERBatcher`, which coalesces them into bucket-shaped
    super-batches; per-request latency is submit → future resolution.

Asserted invariants (the PR-8 serving contract):
  * batched responses demultiplex to EXACTLY the sequential match sets;
  * steady-state XLA compiles are 0 on BOTH legs (shape buckets);
  * the host ``np.nonzero`` survivor scan never runs — steady serving
    decodes stage 1 from the on-device compaction epilogue only;
  * super-batching yields >= 3x sequential queries/sec at micro-batch 8
    (the small-batch regime whose fixed per-dispatch overhead batching
    exists to amortize).

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.er import ERBatcher, ERService, ServiceConfig, compile_counter
from repro.er.blocking import exponential_block_sizes
from repro.er.compiler import stage1_stats
from repro.er.datasets import _WORDS, _perturb, _prefixes

from .common import print_table, save_rows, timer


def skewed_corpus(n: int, b: int, s: float, seed: int = 0):
    """Titles whose 3-char-prefix blocks realize the Fig. 9 exponential
    skew |Φ_k| ∝ e^{−s·k}."""
    rng = np.random.default_rng(seed)
    sizes = exponential_block_sizes(n, b, s)
    prefixes, _ = _prefixes(b)
    titles = []
    for blk, size in enumerate(sizes):
        w = rng.integers(0, len(_WORDS), (size, 2))
        serial = rng.integers(0, 10_000, size)
        titles.extend(
            f"{prefixes[blk]} {_WORDS[a]} {_WORDS[c]} {v:04d}"
            for a, c, v in zip(w[:, 0], w[:, 1], serial))
    rng.shuffle(titles)
    return titles, rng


def _pct(lat, q) -> float:
    return round(1e3 * float(np.percentile(np.asarray(lat), q)), 2)


def run(n: int = 20_000, b: int = 100, batches_per_size: int = 20,
        quick: bool = False):
    if quick:
        n, batches_per_size = 4_000, 6
    titles, rng = skewed_corpus(n, b, s=1.0)
    cfg = ServiceConfig(feature_dim=128, max_len=48, r=32, m=8,
                        query_buckets=(8, 32, 128, 512), tile_chunk=256)

    with timer() as t_ingest:
        svc = ERService(titles, cfg)
    with compile_counter() as warm, timer() as t_warm:
        svc.warmup()

    def make_batch(size: int):
        out = []
        for _ in range(size):
            src = titles[int(rng.integers(0, len(titles)))]
            out.append("" if rng.random() < 0.02 else _perturb(rng, src))
        return out

    rows = []
    for size in cfg.query_buckets:
        micro = [make_batch(size) for _ in range(batches_per_size)]
        nq = batches_per_size * size
        pre = dict(svc.stats)
        nz0 = stage1_stats["nonzero_decodes"]

        # ---- sequential leg: one match() per micro-batch ----
        seq_lat, seq_resp = [], []
        with compile_counter() as steady, timer() as t_seq:
            for q in micro:
                with timer() as tq:
                    seq_resp.append(set(svc.match(q)))
                seq_lat.append(tq.seconds)
        planned = svc.stats["planned_pairs"] - pre["planned_pairs"]

        # ---- batched leg: SAME micro-batches, submitted concurrently,
        # coalesced into bucket-shaped super-batches ----
        bat_lat = {}
        submit_at = {}
        with compile_counter() as bsteady, timer() as t_bat:
            with ERBatcher(svc, max_delay_s=0.01) as batcher:
                futs = []
                for i, q in enumerate(micro):
                    submit_at[i] = time.perf_counter()
                    fut = batcher.submit(q)
                    fut.add_done_callback(
                        lambda f, i=i: bat_lat.__setitem__(
                            i, time.perf_counter() - submit_at[i]))
                    futs.append(fut)
                bat_resp = [set(f.result()) for f in futs]
        assert bat_resp == seq_resp, \
            f"batched demux != sequential match sets at size {size}"
        host_nonzero = stage1_stats["nonzero_decodes"] - nz0

        qps_seq = nq / max(t_seq.seconds, 1e-9)
        qps_bat = nq / max(t_bat.seconds, 1e-9)
        rows.append({
            "batch_size": size,
            "batches": batches_per_size,
            "queries_per_s": round(qps_seq, 1),
            "p50_ms": _pct(seq_lat, 50),
            "p95_ms": _pct(seq_lat, 95),
            "batched_qps": round(qps_bat, 1),
            "batched_p50_ms": _pct(list(bat_lat.values()), 50),
            "batched_p95_ms": _pct(list(bat_lat.values()), 95),
            "speedup": round(qps_bat / qps_seq, 2),
            "super_batches": batcher.stats["super_batches"],
            "planned_pairs_per_q": round(planned / max(nq, 1), 1),
            "matches": sum(len(r) for r in seq_resp),
            "steady_compiles": steady.count + bsteady.count,
            "host_nonzero": host_nonzero,
        })
    meta = {
        "n_corpus": n, "blocks": b, "skew_s": 1.0,
        "ingest_s": round(t_ingest.seconds, 3),
        "warmup_s": round(t_warm.seconds, 3),
        "warmup_compiles": warm.count,
    }
    print_table(f"serve_bench — resident index, Fig. 9 skew s=1.0 "
                f"(n={n}, b={b}), sequential vs super-batched", rows)
    print("meta:", meta)
    save_rows("serve_bench", [dict(r, **meta) for r in rows])
    bad = [r for r in rows if r["steady_compiles"]]
    assert not bad, f"steady-state recompiles: {bad}"
    bad = [r for r in rows if r["host_nonzero"]]
    assert not bad, f"host nonzero survivor scans in steady serving: {bad}"
    small = rows[0]
    assert small["speedup"] >= 3.0, \
        f"super-batching speedup at micro-batch {small['batch_size']} " \
        f"fell below 3x: {small['speedup']}"
    return rows


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv)
