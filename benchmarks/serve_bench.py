"""Serving throughput: queries/sec vs batch size on the resident index.

Corpus blocking follows the Fig. 9 robustness setup at s = 1.0 — block
sizes |Φ_k| ∝ e^{−s·k} over b blocks, realized as distinct 3-char
prefixes so the service's own prefix blocking recovers exactly that skew
(the regime where Basic degrades >10× and the balanced two-source plans
must not). Queries are perturbed corpus samples (same generator as the
dataset ground truth) plus a few null-key entries, streamed at each
bucket size after a warmup; reported per batch size: queries/sec,
batches/sec, planned cross pairs per query, and the steady-state XLA
compile count (must be 0 — the shape-bucket contract).

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.er import ERService, ServiceConfig, compile_counter
from repro.er.blocking import exponential_block_sizes
from repro.er.datasets import _WORDS, _perturb, _prefixes

from .common import print_table, save_rows, timer


def skewed_corpus(n: int, b: int, s: float, seed: int = 0):
    """Titles whose 3-char-prefix blocks realize the Fig. 9 exponential
    skew |Φ_k| ∝ e^{−s·k}."""
    rng = np.random.default_rng(seed)
    sizes = exponential_block_sizes(n, b, s)
    prefixes, _ = _prefixes(b)
    titles = []
    for blk, size in enumerate(sizes):
        w = rng.integers(0, len(_WORDS), (size, 2))
        serial = rng.integers(0, 10_000, size)
        titles.extend(
            f"{prefixes[blk]} {_WORDS[a]} {_WORDS[c]} {v:04d}"
            for a, c, v in zip(w[:, 0], w[:, 1], serial))
    rng.shuffle(titles)
    return titles, rng


def run(n: int = 20_000, b: int = 100, batches_per_size: int = 20,
        quick: bool = False):
    if quick:
        n, batches_per_size = 4_000, 6
    titles, rng = skewed_corpus(n, b, s=1.0)
    cfg = ServiceConfig(feature_dim=128, max_len=48, r=32, m=8,
                        query_buckets=(8, 32, 128, 512), tile_chunk=256)

    with timer() as t_ingest:
        svc = ERService(titles, cfg)
    with compile_counter() as warm, timer() as t_warm:
        svc.warmup()

    def make_batch(size: int):
        out = []
        for _ in range(size):
            src = titles[int(rng.integers(0, len(titles)))]
            out.append("" if rng.random() < 0.02 else _perturb(rng, src))
        return out

    rows = []
    for size in cfg.query_buckets:
        pre = dict(svc.stats)
        with compile_counter() as steady, timer() as t:
            for _ in range(batches_per_size):
                svc.match(make_batch(size))
        nq = batches_per_size * size
        planned = svc.stats["planned_pairs"] - pre["planned_pairs"]
        rows.append({
            "batch_size": size,
            "batches": batches_per_size,
            "queries_per_s": round(nq / max(t.seconds, 1e-9), 1),
            "batches_per_s": round(batches_per_size / max(t.seconds, 1e-9), 2),
            "ms_per_batch": round(1e3 * t.seconds / batches_per_size, 2),
            "planned_pairs_per_q": round(planned / max(nq, 1), 1),
            "matches": svc.stats["matches"] - pre["matches"],
            "steady_compiles": steady.count,
        })
    meta = {
        "n_corpus": n, "blocks": b, "skew_s": 1.0,
        "ingest_s": round(t_ingest.seconds, 3),
        "warmup_s": round(t_warm.seconds, 3),
        "warmup_compiles": warm.count,
    }
    print_table(f"serve_bench — resident index, Fig. 9 skew s=1.0 "
                f"(n={n}, b={b})", rows)
    print("meta:", meta)
    save_rows("serve_bench", [dict(r, **meta) for r in rows])
    bad = [r for r in rows if r["steady_compiles"]]
    assert not bad, f"steady-state recompiles: {bad}"
    return rows


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv)
