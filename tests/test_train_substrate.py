"""Training substrate: optimizer math, checkpoint round-trip + elastic
restore, LPT packing, synthetic pipeline, loss decreases over steps."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data import pack_documents, synthetic_lm_batches
from repro.data.packing import lpt_pack
from repro.models import get_model
from repro.train import adamw_init, make_train_step
from repro.train.checkpoint import async_save, latest_step, restore, save
from repro.train.optimizer import AdamWConfig, adamw_update, cosine_lr, global_norm


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clipping_and_norm():
    g = {"a": jnp.full((10,), 100.0)}
    assert float(global_norm(g)) == pytest.approx(np.sqrt(10) * 100)
    params = {"a": jnp.zeros(10)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    p2, _, stats = adamw_update(g, opt, params, cfg)
    assert float(stats["grad_norm"]) > 1.0
    assert bool(jnp.isfinite(p2["a"]).all())


def test_bf16_moments_dtype():
    params = {"w": jnp.zeros((4, 4))}
    opt = adamw_init(params, AdamWConfig(moment_dtype="bfloat16"))
    assert opt["m"]["w"].dtype == jnp.bfloat16


def test_cosine_lr_schedule():
    assert float(cosine_lr(0, peak=1.0, warmup=10, total=100)) < 0.2
    assert float(cosine_lr(10, peak=1.0, warmup=10, total=100)) == pytest.approx(1.0, abs=0.05)
    assert float(cosine_lr(99, peak=1.0, warmup=10, total=100)) < 0.2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
            "step_scale": np.float32(2.5)}
    save(str(tmp_path), tree, step=7, num_shards=3)
    assert latest_step(str(tmp_path)) == 7
    got, step = restore(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(got["layers"]["w"], tree["layers"]["w"])
    assert got["step_scale"] == tree["step_scale"]


def test_checkpoint_async_and_atomic(tmp_path):
    saver = async_save(str(tmp_path), num_shards=2)
    tree = {"w": np.ones((8, 8))}
    saver(tree, 1)
    saver(tree, 2)   # waits for the first, then writes
    saver.wait()
    assert latest_step(str(tmp_path)) == 2
    # no .tmp leftovers
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_elastic_restore(tmp_path):
    """Save from a '4-device' layout, restore and re-shard differently —
    leaves are stored unsharded so any target mesh works."""
    cfg = reduced(ARCHS["smollm-360m"])
    mod = get_model(cfg)
    params = mod.init(cfg, jax.random.key(0))
    save(str(tmp_path), jax.tree.map(np.asarray, params), step=1)
    got, _ = restore(str(tmp_path))
    # jit with a (1,1) mesh — re-sharding happens at dispatch
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    out = jax.jit(lambda p, b: mod.forward(p, b, cfg))(got, batch)
    ref = mod.forward(params, batch, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_lpt_pack_balance():
    rng = np.random.default_rng(0)
    lengths = (rng.zipf(1.6, 200) * 10).clip(1, 5000)
    _, stats = lpt_pack(lengths, 8)
    assert stats["imbalance"] < 1.4  # skewed docs, near-even rows


def test_pack_documents_masks():
    docs = [np.arange(2, 12, dtype=np.int32), np.arange(5, dtype=np.int32)]
    tokens, mask = pack_documents(docs, n_rows=2, row_len=16, pad_id=0, eos_id=1)
    assert tokens.shape == (2, 16)
    assert mask.sum() == (10 + 1) + (5 + 1)


def test_loss_decreases_smoke():
    cfg = reduced(ARCHS["smollm-360m"], vocab=128)
    mod = get_model(cfg)
    params = mod.init(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3)
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=60))
    opt = adamw_init(params, opt_cfg)
    it = synthetic_lm_batches(cfg.vocab, batch=8, seq=32, seed=0)
    losses = []
    for i, batch in zip(range(40), it):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::8]
