"""Streaming ≡ batch equivalence for the resident ER service.

The contract: ingest a corpus once, stream queries in ANY batch order
and size partition, and the union of the served match sets equals a
one-shot ``run_er`` over corpus ++ queries restricted to cross pairs —
exact set equality, against both executors' oracles and for both
two-source planners, including null-key entities on both sides and
queries from never-seen blocks.
"""
import numpy as np
import pytest

from repro.er import (ERConfig, ERService, ServiceConfig, cross_restrict,
                      make_products, run_er)

FEAT = dict(feature_dim=128, max_len=48)


@pytest.fixture(scope="module")
def workload():
    """Seeded skewed corpus + queries exercising every service job: keyed
    queries hitting corpus blocks, null-key queries, null-key corpus
    rows, and a query block the corpus has never seen."""
    ds = make_products(520, seed=2)
    n_c = 440
    corpus = ds.titles[:n_c] + ["", "   "]
    queries = (ds.titles[n_c:500] + ["", "@@@ never seen block 0001",
                                     "@@@ never seen block 0001",
                                     ds.titles[3]])
    return corpus, queries


@pytest.fixture(scope="module")
def oracles(workload):
    corpus, queries = workload
    both = {
        ex: run_er(corpus + queries,
                   ERConfig(r=8, m=4, executor=ex, **FEAT))
        for ex in ("catalog", "reference")
    }
    assert both["catalog"].matches == both["reference"].matches
    return {ex: cross_restrict(res.matches, len(corpus))
            for ex, res in both.items()}


def _stream(service, queries, sizes):
    got, off = set(), 0
    for sz in sizes:
        for a, b in service.match(queries[off:off + sz]):
            got.add((a, b + off))
        off += sz
    assert off == len(queries)
    return got


@pytest.mark.parametrize("strategy", ("pair_range", "block_split"))
def test_stream_equals_batch_over_splits(workload, oracles, strategy):
    corpus, queries = workload
    svc = ERService(corpus, ServiceConfig(
        r=8, m=4, strategy=strategy, query_buckets=(8, 32, 64),
        tile_chunk=64, **FEAT))
    n = len(queries)
    splits = [
        [n],                                   # one shot
        [1] * n,                               # one query at a time
        [5, 1, 17, 40, n - 63],                # ragged micro-batches
    ]
    for sizes in splits:
        got = _stream(ERService(corpus, svc.cfg), queries, sizes)
        assert got == oracles["catalog"]
        assert got == oracles["reference"]


def test_stream_order_invariant(workload, oracles):
    """Permuting the query stream permutes only local indices — the
    cross match set over the whole stream is identical."""
    corpus, queries = workload
    rng = np.random.default_rng(7)
    perm = rng.permutation(len(queries))
    svc = ERService(corpus, ServiceConfig(
        r=8, m=4, query_buckets=(8, 32, 64), tile_chunk=64, **FEAT))
    got_perm = _stream(svc, [queries[int(i)] for i in perm], [13, 29, 7,
                                                              len(queries) - 49])
    got = {(a, int(perm[b])) for a, b in got_perm}
    assert got == oracles["catalog"]


def test_oversized_batch_splits_internally(workload, oracles):
    corpus, queries = workload
    svc = ERService(corpus, ServiceConfig(
        r=8, m=4, query_buckets=(8, 16), tile_chunk=64, **FEAT))
    got = svc.match(queries)                  # len >> top bucket (16)
    assert got == oracles["catalog"]
    assert svc.stats["batches"] == -(-len(queries) // 16)


def test_never_seen_blocks_grow_bdm(workload):
    corpus, queries = workload
    svc = ERService(corpus, ServiceConfig(
        r=8, m=4, query_buckets=(8, 32, 64), tile_chunk=64, **FEAT))
    b0 = svc.bdm.shape[0]
    svc.match(["@@@ never seen block 0001", "zzq another new one"])
    assert svc.bdm.shape[0] >= b0 + 1
    # appended rows are zero: the corpus side of a never-seen block is empty
    assert int(svc.bdm[b0:].sum()) == 0
    assert int(svc.traffic_bdm.sum()) == 2


def test_empty_inputs():
    svc = ERService(["abc one", "abc two"], ServiceConfig(
        query_buckets=(4,), tile_chunk=32, **FEAT))
    assert svc.match([]) == set()
    empty = ERService([], ServiceConfig(query_buckets=(4,), tile_chunk=32,
                                        **FEAT))
    assert empty.match(["abc one"]) == set()
    assert empty.warmup() == 0
