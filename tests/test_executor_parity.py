"""Tile-catalog executor parity: the fused kernel path (interpret-mode
Pallas and the XLA twin) must produce the IDENTICAL match set as the
reference per-reducer numpy path on a seeded skewed dataset, for all
three strategies — plus the catalog-coverage and map_output_size
invariants the executor rests on."""
import numpy as np
import pytest

from repro.core import (compute_bdm, plan_basic, plan_block_split,
                        plan_pair_range, pairs_of_range)
from repro.core.pair_range import entity_range_matrix, map_output_size
from repro.er import ERConfig, make_products, run_er
from repro.er.blocking import exponential_block_ids
from repro.er.executor import (build_catalog, catalog_for_cross,
                               enumerate_catalog_pairs, score_catalog)

STRATEGIES = ("basic", "block_split", "pair_range")


@pytest.fixture(scope="module")
def skewed_ds():
    ds = make_products(1200, seed=11)
    rng = np.random.default_rng(11)
    bid = exponential_block_ids(ds.n, b=30, s=1.0, rng=rng)  # Fig. 9 s=1.0
    return ds, bid


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_catalog_matches_reference_interpret(skewed_ds, strategy):
    ds, bid = skewed_ds
    base = dict(strategy=strategy, r=8, m=4, feature_dim=128, max_len=48)
    ref = run_er(ds.titles, ERConfig(executor="reference", **base),
                 block_ids=bid)
    got = run_er(ds.titles, ERConfig(executor="catalog",
                                     kernel_impl="interpret", **base),
                 block_ids=bid)
    assert got.matches == ref.matches
    assert got.total_pairs == ref.total_pairs
    assert got.map_output_size == ref.map_output_size
    np.testing.assert_array_equal(got.reducer_pairs, ref.reducer_pairs)


def test_catalog_matches_reference_xla(skewed_ds):
    """The production CPU path (batched-matmul XLA twin) agrees too."""
    ds, bid = skewed_ds
    base = dict(strategy="block_split", r=8, m=4, feature_dim=128, max_len=48)
    ref = run_er(ds.titles, ERConfig(executor="reference", **base),
                 block_ids=bid)
    got = run_er(ds.titles, ERConfig(kernel_impl="xla", **base),
                 block_ids=bid)
    assert got.matches == ref.matches


def _bdm_fixture(seed=3, b=12, m=4):
    rng = np.random.default_rng(seed)
    bdm = rng.integers(0, 40, (b, m)).astype(np.int64)
    bdm[rng.random(b) < 0.25] = 0          # empty blocks
    bdm[rng.integers(0, b)] = [1, 0, 0, 0]  # singleton block
    return bdm


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("bm,bn", [(32, 32), (32, 64)])
def test_catalog_covers_plan_exactly(strategy, bm, bn):
    """Every planned pair appears in the catalog exactly once (unordered),
    nothing else does — for unaligned strips, empty and singleton blocks."""
    bdm = _bdm_fixture()
    plan = {"basic": plan_basic, "block_split": plan_block_split,
            "pair_range": plan_pair_range}[strategy](bdm, 5)
    cat = build_catalog(plan, block_m=bm, block_n=bn)
    ea, eb = enumerate_catalog_pairs(cat)
    got = {(min(a, b), max(a, b)) for a, b in zip(ea.tolist(), eb.tolist())}
    assert len(got) == ea.size, "catalog covers some pair twice"

    sizes = bdm.sum(axis=1)
    estart = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    want = set()
    for k, s in enumerate(sizes):
        x, y = np.triu_indices(int(s), k=1)
        want.update(zip((estart[k] + x).tolist(), (estart[k] + y).tolist()))
    assert got == want
    assert cat.total_pairs == len(want)


def test_pair_range_catalog_respects_range_partition():
    """Each catalog entry's pairs stay inside its own range's pair-index
    interval (the reducer column is the range id)."""
    bdm = _bdm_fixture(seed=7)
    plan = plan_pair_range(bdm, 6)
    cat = build_catalog(plan, block_m=32, block_n=32)
    for k in range(plan.r):
        sub = cat.tiles[cat.tiles[:, -1] == k]
        if not sub.shape[0]:
            continue
        from repro.er.executor import TileCatalog
        ea, eb = enumerate_catalog_pairs(TileCatalog(
            tiles=sub, block_m=32, block_n=32, n_rows_a=cat.n_rows_a,
            n_rows_b=cat.n_rows_b, r=plan.r, total_pairs=0))
        _, _, _, ra, rb = pairs_of_range(plan, k)
        want = set(zip(ra.tolist(), rb.tolist()))
        assert set(zip(ea.tolist(), eb.tolist())) == want


def test_cross_catalog_two_source():
    """Rectangular A×B catalog scores against two distinct matrices."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((70, 32)).astype(np.float32)
    b = rng.standard_normal((23, 32)).astype(np.float32)
    a /= np.linalg.norm(a, axis=1, keepdims=True)
    b /= np.linalg.norm(b, axis=1, keepdims=True)
    cat = catalog_for_cross(70, 23, r=3, block_m=32, block_n=32)
    ca, cb = score_catalog(a, cat, b, threshold=0.2, impl="interpret",
                           chunk_tiles=4)
    cos = a @ b.T
    wa, wb = np.nonzero(cos >= 0.2)
    assert set(zip(ca.tolist(), cb.tolist())) == set(zip(wa.tolist(),
                                                         wb.tolist()))


def test_map_output_size_closed_form_equals_bruteforce():
    """The O(r + b) map_output_size equals the brute-force per-pair oracle
    (and run_er no longer emits the -1 sentinel)."""
    rng = np.random.default_rng(5)
    for _ in range(25):
        bdm = rng.integers(0, 25, (rng.integers(1, 10), rng.integers(1, 4)))
        plan = plan_pair_range(bdm, int(rng.integers(1, 7)))
        assert map_output_size(plan) == int(entity_range_matrix(plan).sum())
