"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _unit_rows(n, d, dtype):
    x = RNG.standard_normal((n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# pair_sim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d", [(64, 64, 32), (200, 130, 64),
                                   (128, 128, 256), (257, 31, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("triangular", [False, True])
def test_pair_scores_sweep(m, n, d, dtype, triangular):
    if triangular and m != n:
        n = m
    a = _unit_rows(m, d, dtype)
    b = a if triangular else _unit_rows(n, d, dtype)
    got = ops.pair_scores(a, b, threshold=0.3, triangular=triangular,
                          impl="interpret")
    want = ref.pair_scores_ref(a, b, threshold=0.3, triangular=triangular)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block", [64, 128])
def test_pair_scores_blocks(block):
    a = _unit_rows(192, 64, jnp.float32)
    got = ops.pair_scores(a, a, threshold=0.5, triangular=True,
                          block_m=block, block_n=block, impl="interpret")
    want = ref.pair_scores_ref(a, a, threshold=0.5, triangular=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped_mm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,d,e,f,bt", [(256, 32, 2, 48, 128),
                                        (384, 64, 3, 128, 128),
                                        (64, 16, 8, 24, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(t, d, e, f, bt, dtype):
    x = jnp.asarray(RNG.standard_normal((t, d)), dtype)
    te = jnp.asarray(RNG.integers(0, e, t // bt), jnp.int32)
    te = jnp.sort(te)
    w = jnp.asarray(RNG.standard_normal((e, d, f)) * 0.1, dtype)
    got = ops.grouped_matmul(x, te, w, block_t=bt, impl="interpret")
    want = ref.grouped_matmul_ref(x, te, w, block_t=bt)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kvh,s,d", [(1, 4, 4, 128, 32),
                                         (2, 4, 2, 256, 32),
                                         (1, 8, 1, 512, 64),
                                         (2, 2, 2, 384, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, kvh, s, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, kvh, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, kvh, s, d)), dtype)
    got = ops.attention(q, k, v, causal=True, block_q=128, block_k=128,
                        impl="interpret")
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    got = ops.attention(q, k, v, causal=False, block_q=128, block_k=128,
                        impl="interpret")
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_chunked_xla_attention_equals_ref():
    """The scanned-q XLA path (production fallback) vs plain softmax.

    gqa_attention uses the g-major flat-head layout (flat h = g·KV + k —
    see sharding.attn_logits_constrain); attention_ref repeats kv heads
    (kv-major, h = k·G + g), so the reference's head axis is permuted
    before comparison."""
    from repro.models.layers import gqa_attention

    h, kv = 6, 3
    q = jnp.asarray(RNG.standard_normal((2, 2048, h, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2048, kv, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2048, kv, 16)), jnp.float32)
    got = gqa_attention(q, k, v, causal=True)   # chunked (S > 1024)
    # g-major: flat query head h attends kv head (h % kv); expand k/v
    # accordingly and compare against a plain MHA reference
    idx = jnp.arange(h) % kv
    want = ref.attention_ref(q.transpose(0, 2, 1, 3),
                             k[:, :, idx].transpose(0, 2, 1, 3),
                             v[:, :, idx].transpose(0, 2, 1, 3), causal=True
                             ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
