"""The async super-batching serving front-end (DESIGN.md §Serving
pipeline) and the on-device survivor-compaction epilogue it rides on.

Contracts under test:

  * flush policy: a full super-batch flushes immediately
    (``flush_full``), an under-full one flushes when the OLDEST member's
    ``max_delay_s`` budget is spent (``flush_deadline``);
  * batched ≡ sequential: any interleaving of concurrent submissions
    (deterministic seeded sweep + hypothesis leg) demultiplexes to
    EXACTLY the per-request sequential ``ERService.match`` sets —
    including requests larger than the super-batch cap;
  * per-tenant token-bucket admission isolates a hot tenant from the
    shared pipeline and advertises an honest ``retry_after_s``;
  * super-batched serving stays at ZERO steady-state XLA recompiles;
  * compaction parity: the packed prefix-sum epilogue (pallas-interpret
    kernel and its XLA twin) reproduces the dense-mask survivors slot
    for slot — counts exact even past capacity, overflow falls back to
    an exact mask decode — and the compact catalog executor equals the
    reference executor end to end.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import compute_bdm, plan_pair_range
from repro.er import (AdmissionError, ERBatcher, ERConfig, ERService,
                      MatchResponse, ServiceConfig, compile_counter,
                      make_products, run_er)
from repro.er.compiler import (lower, plan_to_job, score_catalog,
                               stage1_stats)
from repro.kernels import ops

DS = make_products(250, seed=3)
CORPUS = DS.titles[:140]
QUERIES = DS.titles[140:170]


def _cfg(**kw):
    base = dict(feature_dim=128, max_len=48, r=8, m=4,
                query_buckets=(8, 32), tile_chunk=64)
    base.update(kw)
    return ServiceConfig(**base)


# One quiet oracle service, memoized per micro-batch: the streaming ≡
# batch contract makes every micro-batch's match set a pure function of
# its titles, so answers are reusable across tests and interleavings.
_ORACLE = {}


def _answer(titles):
    key = tuple(titles)
    if key not in _ORACLE:
        if "svc" not in _ORACLE:
            _ORACLE["svc"] = ERService(CORPUS, _cfg())
        _ORACLE[key] = set(_ORACLE["svc"].match(list(titles)))
    return _ORACLE[key]


# ---------------------------------------------------------------------------
# Super-batching: demux exactness and flush policy
# ---------------------------------------------------------------------------

def test_super_batched_results_equal_sequential():
    svc = ERService(CORPUS, _cfg())
    batches = [QUERIES[:5], QUERIES[5:9], QUERIES[9:16], QUERIES[16:24],
               QUERIES[24:30], QUERIES[:3]]
    with ERBatcher(svc, max_delay_s=0.2) as b:
        futs = [b.submit(q) for q in batches]
        for fut, q in zip(futs, batches):
            resp = fut.result(timeout=120)
            assert isinstance(resp, MatchResponse)
            assert set(resp) == _answer(q)
    assert b.stats["requests"] == len(batches)
    assert b.stats["queries"] == sum(len(q) for q in batches)
    # concurrent submissions coalesced into fewer super-batches
    assert 1 <= b.stats["super_batches"] < len(batches)


def test_flush_on_full_does_not_wait_for_the_deadline():
    svc = ERService(CORPUS, _cfg())
    # delay budget is enormous: only the size trigger can flush
    with ERBatcher(svc, max_delay_s=60.0, max_batch=16) as b:
        futs = [b.submit(QUERIES[i * 4:(i + 1) * 4]) for i in range(4)]
        for i, fut in enumerate(futs):
            got = fut.result(timeout=120)     # resolves in << 60 s
            assert set(got) == _answer(QUERIES[i * 4:(i + 1) * 4])
        assert b.stats["flush_full"] == 1
        assert b.stats["flush_deadline"] == 0
        assert b.stats["super_batches"] == 1
        assert b.stats["max_fill"] == 16


def test_flush_on_deadline_bounds_an_underfull_batch():
    svc = ERService(CORPUS, _cfg())
    with ERBatcher(svc, max_delay_s=0.05, max_batch=32) as b:
        t0 = time.monotonic()
        fut = b.submit(QUERIES[:5])           # never fills the batch
        assert set(fut.result(timeout=120)) == _answer(QUERIES[:5])
        waited = time.monotonic() - t0
        assert b.stats["flush_deadline"] == 1
        assert b.stats["flush_full"] == 0
        assert waited >= 0.03                 # it did hold for the window


def test_oversized_request_is_sliced_and_demuxed():
    svc = ERService(CORPUS, _cfg(query_buckets=(8, 16)))
    big = DS.titles[140:230]                  # 90 queries >> top bucket 16
    with ERBatcher(svc, max_delay_s=0.005) as b:
        fut = b.submit(big)
        small = b.submit(QUERIES[:4])
        assert set(fut.result(timeout=240)) == _answer(big)
        assert set(small.result(timeout=240)) == _answer(QUERIES[:4])


def test_closed_batcher_rejects_new_and_empty_resolves_immediately():
    svc = ERService(CORPUS[:30], _cfg())
    b = ERBatcher(svc, max_delay_s=0.005)
    empty = b.submit([])
    assert empty.result(timeout=5) == set()
    assert b.flush(timeout=10)
    b.close()
    with pytest.raises(RuntimeError):
        b.submit(QUERIES[:2])
    b.close()                                 # idempotent


# ---------------------------------------------------------------------------
# Interleavings: batched ≡ sequential, deterministic sweep + hypothesis
# ---------------------------------------------------------------------------

def _submit_interleaved(batcher, batches, staggers):
    results = [None] * len(batches)

    def worker(i):
        time.sleep(float(staggers[i]))
        results[i] = batcher.submit(batches[i]).result(timeout=240)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(batches))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for got, q in zip(results, batches):
        assert set(got) == _answer(q)


def _partition(cuts):
    bounds = [0] + sorted(cuts) + [len(QUERIES)]
    return [QUERIES[lo:hi] for lo, hi in zip(bounds, bounds[1:]) if hi > lo]


def test_interleaved_submissions_match_sequential_sweep():
    svc = ERService(CORPUS, _cfg())
    rng = np.random.default_rng(11)
    with ERBatcher(svc, max_delay_s=0.01) as b:
        for _ in range(4):
            k = int(rng.integers(1, 6))
            cuts = rng.choice(np.arange(1, len(QUERIES)), size=k,
                              replace=False).tolist()
            batches = _partition(cuts)
            _submit_interleaved(b, batches,
                                rng.uniform(0.0, 0.01, len(batches)))


try:                                          # optional dep — the fuzz leg
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _HYP = {}

    def _hyp_batcher() -> ERBatcher:
        # one service + batcher across examples: corpus-side state never
        # changes, so per-batch answers stay pure functions of titles
        if "b" not in _HYP:
            _HYP["b"] = ERBatcher(ERService(CORPUS, _cfg()),
                                  max_delay_s=0.005)
        return _HYP["b"]

    @settings(max_examples=12, deadline=None)
    @given(cuts=st.sets(st.integers(1, len(QUERIES) - 1), max_size=6),
           data=st.data())
    def test_any_interleaving_matches_sequential(cuts, data):
        batches = _partition(list(cuts))
        staggers = [data.draw(st.floats(0.0, 0.01)) for _ in batches]
        _submit_interleaved(_hyp_batcher(), batches, staggers)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_interleaving_matches_sequential():
        pass


# ---------------------------------------------------------------------------
# Admission control and the recompile guard
# ---------------------------------------------------------------------------

def test_tenant_admission_isolates_hot_tenant():
    svc = ERService(CORPUS, _cfg())
    with ERBatcher(svc, max_delay_s=0.01, tenant_rate=40.0,
                   tenant_burst=8.0) as b:
        hot = [b.submit(QUERIES[:4], tenant="hot"),
               b.submit(QUERIES[4:8], tenant="hot")]     # burst spent
        with pytest.raises(AdmissionError) as ei:
            b.submit(QUERIES[8:12], tenant="hot")
        assert ei.value.tenant == "hot"
        assert ei.value.retry_after_s > 0.0
        # a quiet tenant rides the shared pipeline untouched
        cool = b.submit(QUERIES[8:12], tenant="cool")
        assert set(cool.result(timeout=120)) == _answer(QUERIES[8:12])
        for fut, q in zip(hot, [QUERIES[:4], QUERIES[4:8]]):
            assert set(fut.result(timeout=120)) == _answer(q)
        assert b.stats["rejected"] == 1
        # the advertised wait is honest: the bucket has refilled by then
        time.sleep(ei.value.retry_after_s + 0.05)
        ok = b.submit(QUERIES[8:12], tenant="hot")
        assert set(ok.result(timeout=120)) == _answer(QUERIES[8:12])


def test_super_batched_serving_stays_zero_recompile():
    svc = ERService(CORPUS, _cfg())
    svc.warmup()
    with compile_counter() as cc:
        with ERBatcher(svc, max_delay_s=0.005) as b:
            futs = [b.submit(QUERIES[(i % 3) * 7:(i % 3) * 7 + 7])
                    for i in range(9)]
            for fut in futs:
                fut.result(timeout=240)
    assert cc.count == 0


# ---------------------------------------------------------------------------
# On-device survivor compaction: kernel / twin / executor parity
# ---------------------------------------------------------------------------

BM = BN = 16


def _feats(n: int, seed: int, dim: int = 32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(n, dim)).astype(np.float32)
    return f / np.linalg.norm(f, axis=1, keepdims=True)


def _small_catalog(sizes, r=4):
    sizes = np.asarray(sizes, np.int64)
    n = int(sizes.sum())
    bdm = compute_bdm(np.repeat(np.arange(sizes.size), sizes),
                      np.zeros(n, np.int64), sizes.size, 1)
    return lower(plan_to_job(plan_pair_range(bdm, r)), BM, BN), n


def _pairs(ra, rb):
    return set(zip(ra.tolist(), rb.tolist()))


@pytest.mark.parametrize("capacity", (4, 32, BM * BN))
def test_compact_epilogue_parity_and_exact_counts(capacity):
    cat, n = _small_catalog([40, 21, 9], r=4)
    f = _feats(n, 0)
    mask = np.asarray(ops.pair_scores_catalog(
        f, f, cat.tiles, threshold=0.0, block_m=BM, block_n=BN,
        impl="xla")).astype(bool)
    flat = mask.reshape(mask.shape[0], -1)
    counts_want = flat.sum(axis=1)
    assert counts_want.max() > 4              # small caps DO overflow here
    outs = {}
    for impl in ("interpret", "xla"):
        packed, counts = ops.pair_scores_catalog_compact(
            f, f, cat.tiles, threshold=0.0, block_m=BM, block_n=BN,
            capacity=capacity, impl=impl)
        packed = np.asarray(packed)
        counts = np.asarray(counts).reshape(-1)
        # counts stay EXACT even when survivors exceed the capacity —
        # that is what lets the executor detect overflow host-side
        assert (counts == counts_want).all()
        # packed slots are the first min(count, capacity) survivors in
        # row-major order (the order np.nonzero would scan them in)
        for t in range(flat.shape[0]):
            pos = np.flatnonzero(flat[t])
            k = min(pos.size, capacity)
            assert (packed[t, :k] == pos[:k]).all()
            assert (packed[t, k:] == 0).all()  # dead slots zeroed
        outs[impl] = packed
    assert (outs["interpret"] == outs["xla"]).all()


def test_score_catalog_compact_path_equals_mask_path():
    cat, n = _small_catalog([50, 30, 11], r=6)
    f = _feats(n, 1)
    kw = dict(threshold=0.3, impl="xla", chunk_tiles=8)
    before = dict(stage1_stats)
    want = _pairs(*score_catalog(f, cat, compact=False, **kw))
    assert stage1_stats["nonzero_decodes"] > before["nonzero_decodes"]

    before = dict(stage1_stats)
    got = _pairs(*score_catalog(f, cat, compact=True, **kw))
    assert got == want
    # the default capacity (bm·bn) can never overflow: every chunk took
    # the packed epilogue, the host nonzero scan never ran
    assert stage1_stats["compact_decodes"] > before["compact_decodes"]
    assert stage1_stats["nonzero_decodes"] == before["nonzero_decodes"]
    assert stage1_stats["compact_overflows"] == before["compact_overflows"]


def test_compact_overflow_falls_back_exactly():
    cat, n = _small_catalog([50, 30, 11], r=6)
    f = _feats(n, 1)
    kw = dict(threshold=-1.0, impl="xla", chunk_tiles=8)  # ALL pairs live
    want = _pairs(*score_catalog(f, cat, compact=False, **kw))
    before = dict(stage1_stats)
    got = _pairs(*score_catalog(f, cat, compact=True, compact_capacity=2,
                                **kw))
    assert got == want                        # exactness over speed
    assert stage1_stats["compact_overflows"] > before["compact_overflows"]
    assert stage1_stats["nonzero_decodes"] > before["nonzero_decodes"]


def test_run_er_compact_executor_equals_reference():
    titles = DS.titles[:160]
    base = dict(r=8, m=4, feature_dim=128, max_len=48)
    want = run_er(titles, ERConfig(executor="reference", **base)).matches
    for cap in (None, 64):
        got = run_er(titles, ERConfig(executor="catalog",
                                      compact_capacity=cap, **base))
        assert got.matches == want
