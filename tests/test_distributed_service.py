"""Sharded-index ER service on 8 simulated devices (subprocess: the
device count must be pinned before jax initializes). Asserts the
acceptance contract end to end: streaming ≡ batch exact match-set
equality AND zero steady-state recompiles on the 8-device path, plus the
reducer → device routing invariant of the tile shards."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.er import (ERConfig, ERService, ServiceConfig, compile_counter,
                          cross_restrict, make_products, run_er)
    from repro.er.distributed import (device_assignment, match_catalog_2src_dist,
                                      plan_tiles_for_devices)
    from repro.er.executor import RED, catalog_for_two_source, verify_pairs

    try:
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:
        mesh = jax.make_mesh((8,), ("data",))

    ds = make_products(600, seed=5)
    corpus = ds.titles[:500] + [""]
    queries = ds.titles[500:560] + ["", "@@@ fresh block"]
    cfg = ServiceConfig(feature_dim=128, max_len=48, r=16, m=8,
                        query_buckets=(8, 32, 64), tile_chunk=64)
    svc = ERService(corpus, cfg, mesh=mesh)
    svc.warmup()

    # ---- streaming == batch over the sharded index ----
    got, off = set(), 0
    for sz in (9, 33, 13, 7):
        for a, b in svc.match(queries[off:off+sz]):
            got.add((a, b + off))
        off += sz
    assert off == len(queries)
    oracle = run_er(corpus + queries,
                    ERConfig(feature_dim=128, max_len=48, r=16, m=8))
    want = cross_restrict(oracle.matches, len(corpus))
    assert got == want, (len(got), len(want))
    print("sharded stream==batch OK:", len(got), "matches")

    # ---- zero steady-state recompiles on the mesh ----
    rng = np.random.default_rng(0)
    with compile_counter() as steady:
        for _ in range(20):
            sz = int(rng.integers(1, 65))
            svc.match([queries[int(rng.integers(0, len(queries)))]
                       for _ in range(sz)])
    assert steady.count == 0, steady.count
    print("sharded zero-recompile OK")

    # ---- tile shards route reducer -> device round-robin ----
    from repro.core import compute_bdm
    from repro.core.two_source import TwoSourceBDM, plan_pair_range_2src
    qb = np.asarray([0, 0, 1, 2] * 4)
    bdm2 = TwoSourceBDM(
        bdm_r=compute_bdm(np.arange(16) % 3, np.zeros(16, np.int64), 3, 1),
        bdm_s=compute_bdm(qb, np.zeros_like(qb), 3, 1))
    plan = plan_pair_range_2src(bdm2, 16)
    cat = catalog_for_two_source(plan, 16, 16)
    tiles_dev = plan_tiles_for_devices(cat, 8)
    dev_of = device_assignment(16, 8)
    for d in range(8):
        mine = tiles_dev[d]
        live = mine[mine[:, 3] > 0]          # R1 > 0: real entries
        assert all(dev_of[red] == d for red in live[:, RED].tolist())
    print("reducer routing OK")

    # ---- one-shot match_catalog_2src_dist == host cosine oracle ----
    from repro.er.executor import catalog_for_cross
    from repro.er.pipeline import featurize
    from jax.sharding import NamedSharding, PartitionSpec as P
    _, _, cf = featurize(corpus[:64], cfg)     # 64 rows: 8 per device
    _, _, qf = featurize(queries[:16], cfg)
    cf_sharded = jax.device_put(cf, NamedSharding(mesh, P("data")))
    cross = catalog_for_cross(64, 16, r=16, block_m=16, block_n=16)
    ca, cb = match_catalog_2src_dist(cf_sharded, qf, cross, mesh,
                                     threshold=0.55, chunk_tiles=32)
    wa, wb = np.nonzero(cf @ qf.T >= 0.55)
    assert set(zip(ca.tolist(), cb.tolist())) == \
        set(zip(wa.tolist(), wb.tolist()))
    print("one-shot 2src dist OK:", ca.size, "survivors")
""")


@pytest.mark.slow
def test_distributed_service_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for tag in ("sharded stream==batch OK", "sharded zero-recompile OK",
                "reducer routing OK", "one-shot 2src dist OK"):
        assert tag in proc.stdout, proc.stdout + proc.stderr
