"""Property-based tests (hypothesis) on the paper's enumeration math —
the invariants everything else rests on:

  * cell_index / invert_cell_index are mutually inverse bijections onto
    [0, N(N-1)/2);
  * global pair_index bijects onto [0, P) across arbitrary block-size
    vectors (incl. 0- and 1-entity blocks);
  * PairRange ranges partition the pair space with the ceil split of
    Alg. 2 (first r-1 ranges ⌈P/r⌉ pairs);
  * greedy LPT respects the classic (4/3 − 1/3r)·OPT makespan bound and
    conserves total work;
  * BlockSplit match tasks cover each split block's pair set exactly
    once (disjoint ∪ exhaustive);
  * the jnp closed-form inverse equals the numpy oracle for every p;
  * the Sorted Neighborhood band enumeration bijects onto [0, P), its
    range partition covers every band pair exactly once, and the O(r)
    closed-form map_output_size equals the brute-force gather count.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — skip, don't kill collection
from hypothesis import given, settings, strategies as st

from repro.core import enumeration as en
from repro.core import sorted_neighborhood as sn
from repro.core.assignment import greedy_lpt
from repro.core import (compute_bdm, entity_indices, plan_block_split,
                        plan_pair_range, pairs_of_range, update_bdm)
from repro.core.pair_range import pairs_of_range_jnp

sizes_strategy = st.lists(st.integers(0, 60), min_size=1, max_size=30)


@given(st.integers(2, 512))
@settings(max_examples=40, deadline=None)
def test_cell_index_bijection(n):
    q = np.arange(n * (n - 1) // 2, dtype=np.int64)
    x, y = en.invert_cell_index(q, np.int64(n))
    assert (0 <= x).all() and (x < y).all() and (y < n).all()
    np.testing.assert_array_equal(en.cell_index(x, y, n), q)


@given(sizes_strategy)
@settings(max_examples=60, deadline=None)
def test_pair_index_bijection_across_blocks(sizes):
    sizes = np.asarray(sizes, np.int64)
    counts = en.block_pair_counts(sizes)
    offsets, total = en.pair_offsets(counts)
    if total == 0:
        return
    p = np.arange(total, dtype=np.int64)
    blk, x, y = en.invert_pair_index(p, sizes, offsets)
    assert (x < y).all()
    assert (y < sizes[blk]).all()
    np.testing.assert_array_equal(en.pair_index(blk, x, y, sizes, offsets), p)


@given(sizes_strategy, st.integers(1, 40))
@settings(max_examples=60, deadline=None)
def test_range_bounds_partition(sizes, r):
    sizes = np.asarray(sizes, np.int64)
    _, total = en.pair_offsets(en.block_pair_counts(sizes))
    bounds = en.range_bounds(total, r)
    assert bounds.shape == (r, 2)
    assert bounds[0, 0] == 0
    assert bounds[-1, 1] == total
    # contiguity + ceil split (paper Alg. 2)
    per = -(-total // r) if total else 0
    for k in range(r - 1):
        assert bounds[k, 1] == bounds[k + 1, 0]
        assert bounds[k, 1] - bounds[k, 0] in (per, max(total - k * per, 0))


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=200),
       st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_greedy_lpt_bound_and_conservation(weights, r):
    w = np.asarray(weights, np.int64)
    assignment, loads = greedy_lpt(w, r)
    assert loads.sum() == w.sum()
    np.testing.assert_array_equal(
        np.bincount(assignment, weights=w, minlength=r).astype(np.int64), loads)
    opt_lb = max(float(w.sum()) / r, float(w.max()) if w.size else 0.0)
    if opt_lb > 0:
        assert loads.max() <= (4 / 3 - 1 / (3 * r)) * opt_lb + 1e-9 or \
            loads.max() <= w.max()  # single dominant task


@given(st.integers(1, 500), st.integers(1, 8), st.integers(1, 24),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_block_split_covers_all_pairs(n, m, r, seed):
    rng = np.random.default_rng(seed)
    # Zipf-ish skewed block ids
    blocks = (rng.zipf(1.5, size=n) - 1) % max(n // 4, 1)
    parts = rng.integers(0, m, n)
    bdm = compute_bdm(blocks, parts, int(blocks.max()) + 1, m)
    plan = plan_block_split(bdm, r)
    # enumerate every task's pairs in the blocked layout and check the
    # union is exactly the within-block pair set
    got = set()
    for t in range(plan.task_block.shape[0]):
        a0, al = int(plan.task_a_start[t]), int(plan.task_a_len[t])
        b0, bl = int(plan.task_b_start[t]), int(plan.task_b_len[t])
        if plan.task_triangular[t]:
            for i in range(al):
                for j in range(i + 1, al):
                    pair = (a0 + i, a0 + j)
                    assert pair not in got
                    got.add(pair)
        else:
            for i in range(al):
                for j in range(bl):
                    pair = tuple(sorted((a0 + i, b0 + j)))
                    assert pair not in got
                    got.add(pair)
    assert len(got) == plan.total_pairs
    assert plan.reducer_pairs.sum() == plan.total_pairs


@given(sizes_strategy, st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_pair_range_materialization_partitions(sizes, r):
    sizes = np.asarray(sizes, np.int64)
    m = 2
    bdm = np.stack([sizes - sizes // 2, sizes // 2], axis=1)
    plan = plan_pair_range(bdm, r)
    seen = set()
    for k in range(r):
        blk, x, y, ra, rb = pairs_of_range(plan, k)
        for t in zip(blk.tolist(), x.tolist(), y.tolist()):
            assert t not in seen
            seen.add(t)
    assert len(seen) == plan.total_pairs


@given(st.integers(0, 300), st.integers(1, 40))
@settings(max_examples=60, deadline=None)
def test_sn_band_index_bijection(n, w):
    total = sn.band_pair_count(n, w)
    assert total == sum(min(w - 1, n - 1 - i) for i in range(max(n - 1, 0)))
    if total == 0:
        return
    p = np.arange(total, dtype=np.int64)
    i, j = sn.invert_band_index(p, n, w)
    assert (0 <= i).all() and (i < j).all() and (j < n).all()
    assert (j - i < max(w, 2)).all()
    np.testing.assert_array_equal(sn.band_pair_index(i, j, n, w), p)


@given(st.integers(0, 250), st.integers(1, 30), st.integers(1, 24))
@settings(max_examples=60, deadline=None)
def test_sn_ranges_partition_band(n, w, r):
    """Every band pair lands in exactly one reduce task; loads conserve."""
    plan = sn.plan_sorted_neighborhood(n, w, r)
    seen = set()
    for k in range(r):
        ra, rb = sn.pairs_of_band_range(plan, k)
        assert ra.shape == rb.shape == (int(plan.reducer_pairs[k]),)
        for t in zip(ra.tolist(), rb.tolist()):
            assert t not in seen
            seen.add(t)
    want = {(i, j) for i in range(n) for j in range(i + 1, min(i + w, n))}
    assert seen == want
    assert int(plan.reducer_pairs.sum()) == plan.total_pairs == len(want)


@given(st.integers(0, 200), st.integers(1, 30), st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_sn_map_output_size_closed_form(n, w, r):
    """O(r) gather-interval math == brute-force per-pair gather count."""
    plan = sn.plan_sorted_neighborhood(n, w, r)
    brute = 0
    for k in range(r):
        ra, rb = sn.pairs_of_band_range(plan, k)
        brute += len(set(ra.tolist()) | set(rb.tolist()))
        ivs = sn.band_range_intervals(plan, k)
        assert len(ivs) <= 2                     # the ≤2-interval bound
    assert sn.map_output_size(plan) == brute


@given(st.lists(st.tuples(st.integers(0, 12), st.integers(0, 3)),
                min_size=0, max_size=60),
       st.lists(st.integers(0, 59), min_size=0, max_size=6),
       st.integers(0, 5))
@settings(max_examples=60, deadline=None)
def test_update_bdm_is_compute_bdm_of_concat(stream, cut_points, extra_blocks):
    """Incremental Job 1: folding a (block, partition) stream into the BDM
    batch by batch — ANY split, empty batches included, never-seen blocks
    growing the matrix — equals the one-shot compute_bdm of the
    concatenation. This is the monoid property the resident service's
    ``match()`` path leans on."""
    m = 4
    blocks = np.asarray([b for b, _ in stream], np.int64)
    parts = np.asarray([p for _, p in stream], np.int64)
    nb = int(blocks.max()) + 1 if blocks.size else 0
    nb_forced = nb + extra_blocks            # trailing never-seen blocks
    want = compute_bdm(blocks, parts, nb_forced, m)

    cuts = sorted({min(c, len(stream)) for c in cut_points})
    edges = [0] + cuts + [len(stream)]
    bdm = np.zeros((0, m), np.int64)         # empty seed: identity element
    for lo, hi in zip(edges[:-1], edges[1:]):  # empty slices allowed
        bdm = update_bdm(bdm, blocks[lo:hi], parts[lo:hi])
    bdm = update_bdm(bdm, np.zeros(0, np.int64), np.zeros(0, np.int64),
                     num_blocks=nb_forced)   # growth without entities
    np.testing.assert_array_equal(bdm, want)
    # a second empty fold is a no-op, and the input is never mutated
    again = update_bdm(bdm, np.zeros(0, np.int64), np.zeros(0, np.int64))
    np.testing.assert_array_equal(again, want)


@given(sizes_strategy, st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_jnp_inverse_matches_numpy(sizes, r):
    import jax.numpy as jnp

    sizes = np.asarray(sizes, np.int64)
    bdm = sizes[:, None]
    plan = plan_pair_range(bdm, r)
    if plan.total_pairs == 0:
        return
    n_dev = r
    cap = -(-plan.total_pairs // n_dev)
    for dev in range(n_dev):
        ra, rb, valid = pairs_of_range_jnp(
            jnp.asarray(plan.block_sizes, jnp.int32),
            jnp.asarray(plan.offsets, jnp.int32),
            jnp.asarray(plan.estart, jnp.int32),
            jnp.asarray(dev * cap, jnp.int32), cap, plan.total_pairs)
        lo = dev * cap
        hi = min(lo + cap, plan.total_pairs)
        if hi <= lo:
            assert not bool(np.asarray(valid).any())
            continue
        blk, x, y = en.invert_pair_index(
            np.arange(lo, hi), plan.block_sizes, plan.offsets)
        np.testing.assert_array_equal(
            np.asarray(ra)[: hi - lo], plan.estart[blk] + x)
        np.testing.assert_array_equal(
            np.asarray(rb)[: hi - lo], plan.estart[blk] + y)
        assert bool(np.asarray(valid)[: hi - lo].all())
