"""Distributed ER runtime on 8 simulated devices (subprocess: the device
count must be pinned before jax initializes, and the main test session
runs single-device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import compute_bdm, entity_indices, blocked_layout, plan_pair_range, plan_basic
    from repro.er.blocking import prefix_block_ids
    from repro.er.datasets import make_products
    from repro.er.encode import encode_titles, ngram_features
    from repro.er.distributed import (compute_bdm_sharded, match_catalog_dist,
                                      match_pair_range_dist,
                                      match_shards_hostplan, plan_rows_for_devices,
                                      device_assignment)
    from repro.er.executor import build_catalog, verify_pairs
    from repro.er.pipeline import run_er, ERConfig

    try:  # axis_types appeared in newer jax; default is fine where absent
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:
        mesh = jax.make_mesh((8,), ("data",))
    n_dev = 8

    ds = make_products(1024, seed=5)
    bid, _ = prefix_block_ids(ds.titles, ds.prefix_len)
    n = ds.n - (ds.n % n_dev)      # shard-divisible prefix
    titles = ds.titles[:n]
    bid = bid[:n]
    num_blocks = int(bid.max()) + 1

    # ---- Job 1 on the mesh equals the host BDM ----
    part = np.repeat(np.arange(n_dev), n // n_dev)
    bdm_host = compute_bdm(bid, part, num_blocks, n_dev)
    bdm_mesh = np.asarray(compute_bdm_sharded(
        jnp.asarray(bid, jnp.int32), num_blocks, mesh))
    np.testing.assert_array_equal(bdm_host, bdm_mesh)
    print("BDM OK")

    # ---- Job 2 (PairRange, fully in-jit) equals the host pipeline ----
    codes, lens = encode_titles(titles, 48)
    feats = ngram_features(codes, dim=128, lengths=lens)
    eidx = entity_indices(bid, part, bdm_host)
    plan = plan_pair_range(bdm_host, n_dev)
    perm, estart = blocked_layout(bid, eidx, plan.block_sizes)
    fb = jnp.asarray(feats[perm]); cb = jnp.asarray(codes[perm]); lb = jnp.asarray(lens[perm])
    ra, rb, mask, score = match_pair_range_dist(fb, cb, lb, plan, mesh)
    got = set()
    ra, rb, mask = np.asarray(ra), np.asarray(rb), np.asarray(mask)
    for d in range(n_dev):
        for a, b, m in zip(ra[d], rb[d], mask[d]):
            if m:
                ga, gb = int(perm[a]), int(perm[b])
                got.add((min(ga, gb), max(ga, gb)))
    res = run_er(titles, ERConfig(strategy="pair_range", r=n_dev, m=n_dev,
                                  feature_dim=128, max_len=48,
                                  match_missing_keys=False))
    assert got == res.matches, (len(got), len(res.matches))
    print("PairRange dist OK:", len(got), "matches")

    # ---- hostplan executor (Basic) finds the same matches ----
    bplan = plan_basic(bdm_host, n_dev)
    rows = [(np.zeros(0, np.int64), np.zeros(0, np.int64)) for _ in range(n_dev)]
    sizes = plan.block_sizes
    for k_blk in range(num_blocks):
        if sizes[k_blk] < 2: continue
        x, y = np.triu_indices(int(sizes[k_blk]), k=1)
        r = int(bplan.block_reducer[k_blk])
        pa, pb = rows[r]
        rows[r] = (np.concatenate([pa, estart[k_blk] + x]),
                   np.concatenate([pb, estart[k_blk] + y]))
    rows_a, rows_b, valid = plan_rows_for_devices(rows, n_dev, n_dev)
    mask2, _ = match_shards_hostplan(fb, cb, lb,
                                     jnp.asarray(rows_a), jnp.asarray(rows_b),
                                     jnp.asarray(valid), mesh)
    got2 = set()
    mask2 = np.asarray(mask2)
    for d in range(n_dev):
        for a, b, m in zip(rows_a[d], rows_b[d], mask2[d]):
            if m:
                ga, gb = int(perm[a]), int(perm[b])
                got2.add((min(ga, gb), max(ga, gb)))
    assert got2 == res.matches
    print("hostplan dist OK")

    # ---- tile-catalog executor on the mesh (Basic + BlockSplit + PairRange,
    # stage 1 per-device tile shards, stage 2 host verify) ----
    from repro.core import plan_block_split
    for mk_plan in (lambda: bplan, lambda: plan_block_split(bdm_host, n_dev),
                    lambda: plan):
        cplan = mk_plan()
        cat = build_catalog(cplan, block_m=128, block_n=128)
        ca, cb = match_catalog_dist(fb, cat, mesh, threshold=0.8 - 0.25)
        ha, hb = verify_pairs(codes[perm], lens[perm], codes[perm], lens[perm],
                              ca, cb, 0.8)
        got3 = set()
        for a, b in zip(ha, hb):
            ga, gb = int(perm[a]), int(perm[b])
            got3.add((min(ga, gb), max(ga, gb)))
        assert got3 == res.matches, (type(cplan).__name__, len(got3), len(res.matches))
    print("catalog dist OK")

    # ---- elasticity: reducers respread over healthy devices ----
    healthy = np.ones(n_dev, bool); healthy[[2, 5]] = False
    assign = device_assignment(32, n_dev, healthy)
    assert set(assign) == set(np.flatnonzero(healthy))
    counts = np.bincount(assign, minlength=n_dev)
    assert counts[2] == 0 and counts[5] == 0
    assert counts[healthy].max() - counts[healthy].min() <= 1
    print("elastic reassignment OK")
""")


@pytest.mark.slow
def test_distributed_er_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for tag in ("BDM OK", "PairRange dist OK", "hostplan dist OK",
                "elastic reassignment OK"):
        assert tag in proc.stdout, proc.stdout + proc.stderr
