"""Distributed Sorted Neighborhood on 8 simulated devices (subprocess —
the device count must be pinned before jax initializes).

Regression: the RepSN boundary-replication path (w−1 halo rows chained
between adjacent shards via ppermute, no all-gather) produces the same
match set as the single-host ``run_er`` SN pipeline, and the replicated
byte volume is strictly below the full all-gather volume. Windows wider
than a shard (w − 1 > n / n_dev) chain ⌈(w−1)/n_loc⌉ hops instead of
raising — the multi-hop leg asserts equality there too, plus the
per-hop byte schedule."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.er import ERConfig, make_products, run_er, sn_sort_order
    from repro.er.encode import encode_titles, ngram_features
    from repro.er.distributed import match_sn_dist, sn_replication_volume
    from repro.er.executor import verify_pairs

    try:
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:
        mesh = jax.make_mesh((8,), ("data",))
    n_dev = 8
    W, DIM, MAXLEN = 64, 128, 48

    ds = make_products(1024, seed=5)
    n = ds.n - (ds.n % n_dev)          # shard-divisible prefix
    titles = ds.titles[:n]

    # ---- single-host SN pipeline (catalog executor) ----
    res = run_er(titles, ERConfig(strategy="sorted_neighborhood", window=W,
                                  r=n_dev, feature_dim=DIM, max_len=MAXLEN))

    # ---- RepSN path: sorted row shards + halo exchange ----
    order = sn_sort_order(titles)
    codes, lens = encode_titles(titles, MAXLEN)
    feats = ngram_features(codes, dim=DIM, lengths=lens)
    fs = jnp.asarray(feats[order])
    ca, cb = match_sn_dist(fs, W, mesh, threshold=0.8 - 0.25)
    ha, hb = verify_pairs(codes[order], lens[order], codes[order],
                          lens[order], ca, cb, 0.8)
    got = set()
    for a, b in zip(ha, hb):
        ga, gb = int(order[a]), int(order[b])
        got.add((min(ga, gb), max(ga, gb)))
    assert got == res.matches, (len(got), len(res.matches))
    print("SN dist OK:", len(got), "matches")

    # ---- boundary replication beats all-gather on the wire ----
    halo_bytes, allgather_bytes = sn_replication_volume(n, W, n_dev, DIM)
    assert halo_bytes < allgather_bytes, (halo_bytes, allgather_bytes)
    assert halo_bytes == n_dev * (W - 1) * DIM * 4
    print(f"SN volume OK: halo {halo_bytes} < all-gather {allgather_bytes}")

    # ---- multi-hop: window wider than a shard (w − 1 > n / n_dev) ----
    W2 = n // n_dev + 2
    res2 = run_er(titles, ERConfig(strategy="sorted_neighborhood",
                                   window=W2, r=n_dev, feature_dim=DIM,
                                   max_len=MAXLEN))
    ca, cb = match_sn_dist(fs, W2, mesh, threshold=0.8 - 0.25)
    ha, hb = verify_pairs(codes[order], lens[order], codes[order],
                          lens[order], ca, cb, 0.8)
    got2 = set()
    for a, b in zip(ha, hb):
        ga, gb = int(order[a]), int(order[b])
        got2.add((min(ga, gb), max(ga, gb)))
    assert got2 == res2.matches, (len(got2), len(res2.matches))
    per_hop = sn_replication_volume(n, W2, n_dev, DIM, per_hop=True)
    assert len(per_hop) == 2 and sum(per_hop) == (W2 - 1) * DIM * 4
    print("SN multi-hop OK:", len(got2), "matches over", len(per_hop),
          "hops")
""")


@pytest.mark.slow
def test_distributed_sn_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for tag in ("SN dist OK", "SN volume OK", "SN multi-hop OK"):
        assert tag in proc.stdout, proc.stdout + proc.stderr
