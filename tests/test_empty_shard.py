"""Empty-device-shard regression: under extreme skew the cost-LPT
schedule can place EVERY tile on a few devices and leave others with a
zero-length shard. ``execute(fixed_chunks=False)`` shrinks the chunk to
the largest device shard — this pins that the shrunken chunk still pads
to >= 1 tile (an all-zero tile has an empty validity window, so idle
devices contribute no survivors) and that the mesh run scores exactly
the single-host survivor set. Runs in a subprocess: the simulated device
count must be pinned before jax initializes."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core import compute_bdm, plan_block_split
    from repro.er.compiler import (execute, lower, plan_to_job,
                                   schedule_tiles, tiles_for_devices)

    try:
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:
        mesh = jax.make_mesh((8,), ("data",))
    n_dev = 8

    # Extreme skew: one dominant block, a couple of tiny ones. With
    # block_m = block_n = 64 the whole job lowers to a handful of tiles,
    # so 8-way LPT necessarily leaves devices empty.
    sizes = np.array([120, 5, 3], np.int64)   # sums to 128: 8-shardable
    n = int(sizes.sum())
    bdm = compute_bdm(np.repeat(np.arange(sizes.size), sizes),
                      np.zeros(n, np.int64), sizes.size, 1)
    plan = plan_block_split(bdm, 4)
    cat = lower(plan_to_job(plan), 64, 64)
    sched = schedule_tiles(cat, n_dev=n_dev, policy="cost_lpt")

    tiles_dev = tiles_for_devices(cat, n_dev, schedule=sched)
    per_dev = np.bincount(
        sched.reducer_device[sched.tile_reducer], minlength=n_dev)
    assert (per_dev == 0).any(), per_dev       # the shard IS empty
    assert tiles_dev.shape[1] >= 1             # ... and still pads to >= 1
    print("empty shard present:", int((per_dev == 0).sum()), "devices idle")

    rng = np.random.default_rng(0)
    feats = rng.normal(size=(n, 64)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)

    want = execute(cat, feats, threshold=0.4)  # single host oracle
    got = execute(cat, feats, threshold=0.4, mesh=mesh, schedule=sched,
                  chunk_tiles=1024, fixed_chunks=False)
    to_set = lambda ab: set(zip(ab[0].tolist(), ab[1].tolist()))
    assert to_set(got) == to_set(want), (len(to_set(got)),
                                         len(to_set(want)))
    assert len(to_set(want)) > 0
    print("empty-shard execute OK:", len(to_set(want)), "survivors")

    # Degenerate end of the same axis: a catalog whose every tile fits
    # ONE device (single tile) — chunk shrinks all the way to 1.
    sizes1 = np.array([40], np.int64)
    n1 = int(sizes1.sum())
    bdm1 = compute_bdm(np.zeros(n1, np.int64), np.zeros(n1, np.int64), 1, 1)
    cat1 = lower(plan_to_job(plan_block_split(bdm1, 1)), 64, 64)
    sched1 = schedule_tiles(cat1, n_dev=n_dev, policy="cost_lpt")
    feats1 = feats[:n1]
    want1 = execute(cat1, feats1, threshold=0.4)
    got1 = execute(cat1, feats1, threshold=0.4, mesh=mesh,
                   schedule=sched1, fixed_chunks=False)
    assert to_set(got1) == to_set(want1)
    print("single-tile catalog OK:", len(to_set(want1)), "survivors")
""")


@pytest.mark.slow
def test_empty_shard_fixed_chunks_false_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for tag in ("empty shard present", "empty-shard execute OK",
                "single-tile catalog OK"):
        assert tag in proc.stdout, proc.stdout + proc.stderr
