"""End-to-end ER system behaviour (paper workflow on one host):
all three strategies find identical matches, recall on injected
duplicates is ~1.0, balance metrics ordered Basic ≫ BlockSplit ≥
PairRange, Fig. 12 map-output ordering, two-source + missing-key paths.
"""
import numpy as np
import pytest

from repro.core import compute_bdm, plan_basic, plan_block_split, plan_pair_range
from repro.core.two_source import (TwoSourceBDM, plan_block_split_2src,
                                   plan_pair_range_2src, pairs_of_range_2src)
from repro.er import ERConfig, make_products, run_er
from repro.er.blocking import exponential_block_ids, prefix_block_ids
from repro.er.similarity import edit_distance, edit_distance_np
from repro.er.encode import encode_titles, ngram_features


@pytest.fixture(scope="module")
def ds():
    # large enough that the generator's head-block pair-share calibration
    # holds (integer rounding washes it out below ~10k entities)
    return make_products(12_000, seed=0)


@pytest.fixture(scope="module")
def results(ds):
    return {
        strat: run_er(ds.titles, ERConfig(strategy=strat, r=16, m=8))
        for strat in ("basic", "block_split", "pair_range")
    }


def test_strategies_agree_and_recall(ds, results):
    match_sets = [r.matches for r in results.values()]
    assert match_sets[0] == match_sets[1] == match_sets[2]
    recall = len(match_sets[0] & ds.true_pairs) / len(ds.true_pairs)
    assert recall >= 0.98
    # precision is not 1.0 (near-duplicate generated titles) but bounded
    assert len(match_sets[0]) < 50 * len(ds.true_pairs)


def test_balance_ordering(results):
    mx = {k: int(v.reducer_pairs.max()) for k, v in results.items()}
    total = results["basic"].total_pairs
    # Basic pinned to the largest block (~70% of pairs); balanced ≈ P/r
    assert mx["basic"] > 0.4 * total
    assert mx["basic"] > 5 * mx["pair_range"]
    assert mx["pair_range"] == -(-total // 16)
    assert mx["block_split"] <= 2 * mx["pair_range"]


def test_map_output_ordering(results):
    # Fig. 12: basic = n (no replication) < block_split <= pair_range-ish
    basic = results["basic"].map_output_size
    bs = results["block_split"].map_output_size
    assert basic < bs


def test_skewed_blocking_override(ds):
    rng = np.random.default_rng(0)
    bid = exponential_block_ids(ds.n, b=50, s=1.0, rng=rng)
    res = run_er(ds.titles, ERConfig(strategy="pair_range", r=8, m=4),
                 block_ids=bid)
    assert res.total_pairs > 0
    assert res.reducer_pairs.max() == -(-res.total_pairs // 8)


def test_missing_keys_matched():
    titles = ["", " ", "abc laptop pro 0001", "abc laptop pro 0001"]
    res = run_er(titles, ERConfig(strategy="pair_range", r=2, m=1))
    assert (2, 3) in res.matches
    assert res.extra.get("null_key_pairs", 0) > 0


def test_two_source_plans_cover():
    rng = np.random.default_rng(3)
    bdm2 = TwoSourceBDM(bdm_r=rng.integers(0, 5, (6, 2)),
                        bdm_s=rng.integers(0, 5, (6, 3)))
    total = int((bdm2.sizes_r * bdm2.sizes_s).sum())
    p2 = plan_pair_range_2src(bdm2, 4)
    assert p2.total_pairs == total
    seen = set()
    for k in range(4):
        blk, x, y, rr, rs = pairs_of_range_2src(p2, k)
        for t in zip(blk.tolist(), x.tolist(), y.tolist()):
            assert t not in seen
            seen.add(t)
    assert len(seen) == total
    b2 = plan_block_split_2src(bdm2, 4)
    assert b2.total_pairs == total
    assert b2.reducer_pairs.sum() == total


def test_edit_distance_matches_reference():
    rng = np.random.default_rng(0)
    words = ["kitten", "sitting", "acme laptop pro", "acme laptop pr",
             "zzz", "", "a", "load balancing for mapreduce"]
    pairs = [(a, b) for a in words for b in words]
    ca, la = encode_titles([p[0] for p in pairs], 32)
    cb, lb = encode_titles([p[1] for p in pairs], 32)
    got = np.asarray(edit_distance(ca, la, cb, lb))
    want = [edit_distance_np(a, b) for a, b in pairs]
    np.testing.assert_array_equal(got, want)


def test_default_config_fresh_per_call():
    """run_er must not share a mutable default ERConfig across calls:
    the default is None → a fresh instance, returned on ERResult.config,
    so mutating a returned config cannot leak into later calls."""
    import inspect

    from repro.er.pipeline import run_er as _run_er

    assert inspect.signature(_run_er).parameters["config"].default is None
    titles = ["abc laptop pro 0001", "abc laptop pro 0002",
              "abd phone max 0003", "abd phone max 0004"]
    res1 = run_er(titles)
    assert res1.config is not None and res1.config.threshold == 0.8
    res1.config.threshold = 0.0          # sabotage the returned config
    res1.config.strategy = "basic"
    res2 = run_er(titles)                # fresh default, unaffected
    assert res2.config is not res1.config
    assert res2.config.threshold == 0.8
    assert res2.config.strategy == "pair_range"
    assert res2.matches == res1.matches


def test_ngram_features_unit_norm_and_determinism():
    titles = ["acme laptop", "acme laptop", "zzz", "ab"]
    f1 = ngram_features(titles, dim=64)
    f2 = ngram_features(titles, dim=64)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_allclose(np.linalg.norm(f1, axis=1), 1.0, rtol=1e-5)
    np.testing.assert_array_equal(f1[0], f1[1])
    assert not np.array_equal(f1[0], f1[2])
