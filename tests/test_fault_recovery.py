"""The fault-tolerant runtime's headline contract: for ANY seeded
failure script — device kills, stragglers, transient scorer errors,
corrupted survivor shards, up to n_dev − 1 fatal devices — the
supervised executor returns EXACTLY the failure-free match set, with
retries inside the configured bound and exponential backoff between
recovery rounds.

The hypothesis leg fuzzes random catalogs (every planner) against
random `FaultScript`s; the deterministic leg pins the edge cases:
losing all but one device, losing every device (typed error / partial
mode), retry exhaustion, straggler-timeout discard, and the
exactly-once merge.
"""
import numpy as np
import pytest

from repro.core import compute_bdm, plan_basic, plan_block_split, \
    plan_pair_range, plan_sorted_neighborhood
from repro.er.compiler import (FaultEvent, FaultInjector, FaultScript,
                               NoHealthyDevicesError, RecoveryFailedError,
                               cross_job, execute, execute_supervised,
                               lower, plan_to_job, shard_sane)

BM = BN = 32
THRESH = 0.4


def _feats(n: int, seed: int, dim: int = 32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(n, dim)).astype(np.float32)
    return f / np.linalg.norm(f, axis=1, keepdims=True)


def _catalog(strategy: str, sizes, r: int):
    """Lower a plan over explicit block sizes (1 input partition)."""
    sizes = np.asarray(sizes, np.int64)
    n = int(sizes.sum())
    if strategy == "sorted_neighborhood":
        plan = plan_sorted_neighborhood(n, w=5, r=r)
    else:
        bdm = compute_bdm(np.repeat(np.arange(sizes.size), sizes),
                          np.zeros(n, np.int64), sizes.size, 1)
        plan = {"basic": plan_basic, "block_split": plan_block_split,
                "pair_range": plan_pair_range}[strategy](bdm, r)
    return lower(plan_to_job(plan), BM, BN), n


def _pairs(ra, rb):
    return set(zip(ra.tolist(), rb.tolist()))


def _quiet(catalog, feats, feats_b=None):
    return _pairs(*execute(catalog, feats, feats_b, threshold=THRESH))


# ---------------------------------------------------------------------------
# Deterministic edge cases
# ---------------------------------------------------------------------------

def test_no_injector_equals_execute():
    cat, n = _catalog("pair_range", [60, 17, 5, 1, 40], r=8)
    f = _feats(n, 0)
    ra, rb, rep = execute_supervised(cat, f, threshold=THRESH, n_dev=4)
    assert _pairs(ra, rb) == _quiet(cat, f)
    assert rep.rounds == 1 and rep.retries == 0
    assert rep.recovered_tiles == 0 and rep.coverage == 1.0


def test_survives_all_but_one_device():
    cat, n = _catalog("block_split", [90, 33, 12, 4], r=8)
    f = _feats(n, 1)
    script = FaultScript(events=tuple(
        FaultEvent("kill", d, 0) for d in range(3)), n_dev=4)
    ra, rb, rep = execute_supervised(
        cat, f, threshold=THRESH, n_dev=4, max_retries=4, backoff=0.0,
        injector=FaultInjector(script))
    assert _pairs(ra, rb) == _quiet(cat, f)
    assert rep.coverage == 1.0
    assert rep.healthy.tolist() == [False, False, False, True]


def test_all_devices_dead_raises_typed_error_or_degrades():
    cat, n = _catalog("basic", [50, 20], r=4)
    f = _feats(n, 2)
    script = FaultScript(events=tuple(
        FaultEvent("kill", d, 0) for d in range(3)), n_dev=3)
    with pytest.raises(NoHealthyDevicesError):
        execute_supervised(cat, f, threshold=THRESH, n_dev=3,
                           max_retries=4, backoff=0.0,
                           injector=FaultInjector(script))
    # graceful degradation: partial mode returns what it has instead
    ra, rb, rep = execute_supervised(
        cat, f, threshold=THRESH, n_dev=3, max_retries=4, backoff=0.0,
        partial=True, injector=FaultInjector(script))
    assert ra.size == 0 and rep.coverage == 0.0 and rep.lost_tiles > 0


def test_retry_exhaustion_is_bounded_and_typed():
    cat, n = _catalog("pair_range", [70, 30], r=4)
    f = _feats(n, 3)
    # an endless supply of corruption on the only device
    script = FaultScript(events=tuple(
        FaultEvent("corrupt", 0, 0) for _ in range(50)), n_dev=1)
    with pytest.raises(RecoveryFailedError) as ei:
        execute_supervised(cat, f, threshold=THRESH, n_dev=1,
                           max_retries=2, backoff=0.0,
                           injector=FaultInjector(script))
    assert ei.value.report.retries == 2          # the configured bound
    ra, rb, rep = execute_supervised(
        cat, f, threshold=THRESH, n_dev=1, max_retries=2, backoff=0.0,
        partial=True, injector=FaultInjector(script))
    assert rep.retries == 2 and rep.coverage < 1.0


def test_backoff_is_exponential_and_observed():
    cat, n = _catalog("pair_range", [80, 25], r=4)
    f = _feats(n, 4)
    script = FaultScript(events=(
        FaultEvent("transient", 0, 0), FaultEvent("transient", 0, 2),
        FaultEvent("transient", 0, 4)), n_dev=2)
    slept = []
    ra, rb, rep = execute_supervised(
        cat, f, threshold=THRESH, n_dev=2, max_retries=6,
        backoff=0.01, backoff_factor=3.0, sleep=slept.append,
        injector=FaultInjector(script))
    assert _pairs(ra, rb) == _quiet(cat, f)
    assert slept == rep.backoffs
    for prev, nxt in zip(rep.backoffs, rep.backoffs[1:]):
        assert nxt == pytest.approx(prev * 3.0)


def test_straggler_timeout_discards_and_recovers():
    cat, n = _catalog("block_split", [100, 40], r=8)
    f = _feats(n, 5)
    script = FaultScript(events=(
        FaultEvent("straggle", 1, 0, delay=1e6),), n_dev=4)
    ra, rb, rep = execute_supervised(
        cat, f, threshold=THRESH, n_dev=4, shard_deadline=60.0,
        max_retries=3, backoff=0.0, injector=FaultInjector(script))
    assert _pairs(ra, rb) == _quiet(cat, f)
    statuses = [r.status for r in rep.records]
    assert "timeout" in statuses
    assert not rep.healthy[1]                    # straggler was evicted
    assert rep.coverage == 1.0


def test_merge_is_exactly_once():
    cat, n = _catalog("basic", [64, 64], r=4)
    f = _feats(n, 6)
    script = FaultScript(events=(
        FaultEvent("corrupt", 0, 0), FaultEvent("transient", 1, 0)),
        n_dev=2)
    ra, rb, rep = execute_supervised(
        cat, f, threshold=THRESH, n_dev=2, max_retries=4, backoff=0.0,
        injector=FaultInjector(script))
    pairs = np.stack([ra, rb], axis=1)
    assert np.unique(pairs, axis=0).shape[0] == pairs.shape[0]
    assert _pairs(ra, rb) == _quiet(cat, f)


def test_two_source_catalog_recovers():
    cat = lower(cross_job(130, 37, r=4), BM, BN)
    fa, fb = _feats(130, 7), _feats(37, 8)
    script = FaultScript(events=(
        FaultEvent("kill", 0, 1), FaultEvent("corrupt", 2, 2)), n_dev=3)
    ra, rb, rep = execute_supervised(
        cat, fa, fb, threshold=THRESH, n_dev=3, max_retries=4,
        backoff=0.0, injector=FaultInjector(script))
    assert _pairs(ra, rb) == _quiet(cat, fa, fb)
    assert rep.coverage == 1.0


def test_empty_catalog():
    cat, _ = _catalog("basic", [1], r=2)        # singleton block: no pairs
    assert cat.num_tiles == 0
    ra, rb, rep = execute_supervised(cat, _feats(1, 9), threshold=THRESH,
                                     n_dev=2)
    assert ra.size == 0 and rep.coverage == 1.0


def test_shard_sane_rejects_garbage():
    ok_a = np.array([0, 3], np.int64)
    ok_b = np.array([1, 2], np.int64)
    assert shard_sane(ok_a, ok_b, 4, 4)
    assert not shard_sane(np.array([4], np.int64),
                          np.array([0], np.int64), 4, 4)
    assert not shard_sane(np.array([-1], np.int64),
                          np.array([0], np.int64), 4, 4)
    assert not shard_sane(ok_a, ok_b[:1], 4, 4)
    inj = FaultInjector(FaultScript(events=(), n_dev=1))
    ga, gb = inj.corrupt_output(ok_a, ok_b, 4, 4)
    assert not shard_sane(ga, gb, 4, 4)          # corruption is detectable


def test_fault_script_replay_is_deterministic():
    s1 = FaultScript.random(11, 6, 12, allow_revive=True)
    s2 = FaultScript.random(11, 6, 12, allow_revive=True)
    assert s1 == s2
    cat, n = _catalog("pair_range", [55, 21, 8], r=8)
    f = _feats(n, 10)
    runs = []
    for _ in range(2):
        ra, rb, rep = execute_supervised(
            cat, f, threshold=THRESH, n_dev=6, shard_deadline=60.0,
            max_retries=14, backoff=0.0, injector=FaultInjector(s1))
        runs.append((_pairs(ra, rb), rep.rounds,
                     [r.status for r in rep.records]))
    assert runs[0] == runs[1]


def test_run_er_supervised_equals_quiet_pipeline():
    from repro.er import ERConfig, make_products, run_er
    titles = make_products(250, seed=3).titles[:160]
    cfg = ERConfig(strategy="block_split", r=8, m=4, feature_dim=128,
                   max_len=48, supervised_devices=4, max_retries=6,
                   backoff_s=0.0)
    want = run_er(titles, ERConfig(strategy="block_split", r=8, m=4,
                                   feature_dim=128, max_len=48))
    script = FaultScript(events=(
        FaultEvent("kill", 1, 0), FaultEvent("corrupt", 2, 3)), n_dev=4)
    got = run_er(titles, cfg, fault_injector=FaultInjector(script))
    assert got.matches == want.matches
    assert got.coverage == 1.0 and got.attempts > 1
    assert got.recovered_tiles > 0
    quiet = run_er(titles, cfg)                  # supervised, no chaos
    assert quiet.matches == want.matches
    assert quiet.attempts == 1 and quiet.recovered_tiles == 0


# ---------------------------------------------------------------------------
# Hypothesis: random catalogs × random failure scripts
# ---------------------------------------------------------------------------

try:                                             # optional dep — the fuzz
    from hypothesis import given, settings, strategies as st  # noqa: E402
    HAVE_HYPOTHESIS = True                       # leg skips, the
except ImportError:                              # deterministic leg runs
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    STRATEGIES = ("basic", "block_split", "pair_range",
                  "sorted_neighborhood")

    @st.composite
    def sizes_strategy(draw):
        b = draw(st.integers(1, 5))
        sizes = [draw(st.integers(1, 40)) for _ in range(b)]
        if draw(st.booleans()):                  # a dominant skewed block
            sizes[0] = draw(st.integers(60, 120))
        return sizes

    @settings(max_examples=20, deadline=None)
    @given(sizes=sizes_strategy(),
           strategy=st.sampled_from(STRATEGIES),
           r=st.integers(2, 12),
           n_dev=st.integers(2, 6),
           n_events=st.integers(0, 8),
           seed=st.integers(0, 2**16))
    def test_any_failure_script_recovers_exact_match_set(
            sizes, strategy, r, n_dev, n_events, seed):
        """The recovery invariant, fuzzed: kills / stragglers /
        transients / corruption at random points, up to n_dev − 1 fatal
        devices ⇒ the supervised run returns exactly the failure-free
        candidate set, coverage 1.0, retries within the bound."""
        cat, n = _catalog(strategy, sizes, r)
        f = _feats(n, seed)
        want = _quiet(cat, f)
        script = FaultScript.random(seed, n_dev, n_events, max_step=40,
                                    straggle_delay=1e6)
        max_retries = n_events + 2
        ra, rb, rep = execute_supervised(
            cat, f, threshold=THRESH, n_dev=n_dev, shard_deadline=120.0,
            max_retries=max_retries, backoff=0.0,
            injector=FaultInjector(script, seed=seed))
        assert _pairs(ra, rb) == want
        assert rep.coverage == 1.0 and rep.lost_tiles == 0
        assert rep.retries <= max_retries
        # failed shards never leak survivors: every accepted shard is sane
        for rec in rep.records:
            assert rec.status in ("ok", "killed", "transient", "timeout",
                                  "corrupt")
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_failure_script_recovers_exact_match_set():
        pass
