"""Tile geometry is an execution detail, never a semantics knob: ANY
lattice geometry × {interpret, xla, reference} × {mask, compact} must
reproduce the exact 128×128 match set on Basic / BlockSplit / PairRange
/ SortedNeighborhood catalogs — plus the occupancy model's waste
accounting, the VMEM lowering guard, cost-model state round-trips, the
service warm-start contract, and the mesh-path on-device compaction."""
import numpy as np
import pytest

try:        # hypothesis widens the sweep when present; core parity runs always
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax

from repro.core import (compute_bdm, plan_basic, plan_block_split,
                        plan_pair_range, plan_sorted_neighborhood)
from repro.er import ERService, ServiceConfig, compile_counter, make_products
from repro.er.blocking import exponential_block_ids
from repro.er.compiler import (GEOMETRY_LATTICE, GeometryCostModel,
                               EwmaCostModel, autotune, catalog_occupancy,
                               enumerate_catalog_pairs, execute, lower,
                               plan_to_job, score_catalog, stage1_stats)
from repro.kernels.pair_sim import (VMEM_BUDGET_BYTES, catalog_vmem_bytes,
                                    check_vmem)

N, D, R, M = 220, 32, 5, 4
THRESHOLD = 0.3
JOB_NAMES = ("basic", "block_split", "pair_range", "sn")

_rng = np.random.default_rng(17)
_bid = exponential_block_ids(N, b=12, s=1.0, rng=_rng)
_order = np.argsort(_bid, kind="stable")
FEATS = _rng.standard_normal((N, D)).astype(np.float32)
FEATS /= np.linalg.norm(FEATS, axis=1, keepdims=True)
_bid = _bid[_order]
_BDM = compute_bdm(_bid, np.arange(N, dtype=np.int64) % M,
                   int(np.bincount(_bid).shape[0]), M)

_JOBS = {
    "basic": plan_to_job(plan_basic(_BDM, R)),
    "block_split": plan_to_job(plan_block_split(_BDM, R)),
    "pair_range": plan_to_job(plan_pair_range(_BDM, R)),
    "sn": plan_to_job(plan_sorted_neighborhood(N, w=9, r=R)),
}

# Reference leg: brute-force numpy over the enumerated (geometry-free)
# pair set — every scored configuration below must reproduce it exactly.
_COS = FEATS @ FEATS.T


def _ref_matches(name):
    ea, eb = enumerate_catalog_pairs(lower(_JOBS[name], 128, 128))
    keep = _COS[ea, eb] >= THRESHOLD
    return {(min(a, b), max(a, b))
            for a, b in zip(ea[keep].tolist(), eb[keep].tolist())}


_REF = {name: _ref_matches(name) for name in JOB_NAMES}


def _assert_parity(geom, name, impl, compact):
    cat = lower(_JOBS[name], *geom)
    ra, rb = score_catalog(FEATS, cat, threshold=THRESHOLD, impl=impl,
                           compact=compact, chunk_tiles=64)
    got = {(min(a, b), max(a, b)) for a, b in zip(ra.tolist(), rb.tolist())}
    assert got == _REF[name], (geom, name, impl, compact)


@pytest.mark.parametrize("geom", GEOMETRY_LATTICE)
@pytest.mark.parametrize("name", JOB_NAMES)
def test_full_lattice_parity_xla_compact(geom, name):
    """Every lattice geometry × every catalog family on the production
    CPU path (xla twin + on-device compaction)."""
    _assert_parity(geom, name, "xla", compact=True)


@pytest.mark.parametrize("impl,compact",
                         [("xla", False), ("interpret", False),
                          ("interpret", True)])
@pytest.mark.parametrize("geom", [(32, 64), (64, 32), (128, 128)])
def test_parity_mask_and_interpret_paths(geom, impl, compact):
    """Non-square geometries through the dense-mask decode and the
    interpret-mode kernel emulator (which ignores ``compact``)."""
    _assert_parity(geom, "block_split", impl, compact)


if HAVE_HYPOTHESIS:
    @given(geom=st.sampled_from(GEOMETRY_LATTICE),
           name=st.sampled_from(JOB_NAMES),
           impl=st.sampled_from(("interpret", "xla")),
           compact=st.booleans())
    @settings(max_examples=24, deadline=None)
    def test_any_geometry_reproduces_the_128x128_match_set(
            geom, name, impl, compact):
        _assert_parity(geom, name, impl, compact)


@pytest.mark.parametrize("geom", [(32, 32), (64, 32), (128, 128), (32, 256)])
@pytest.mark.parametrize("name", JOB_NAMES)
def test_occupancy_waste_equals_enumerated_dead_cells(geom, name):
    """The static model's waste is EXACT: cells − Σ tile_costs equals the
    cells not covered by any enumerated live pair, and the live-pair sum
    is geometry-invariant (the plan's own pair total)."""
    job = _JOBS[name]
    cat = lower(job, *geom)
    cells, live, waste = catalog_occupancy(cat)
    ea, _ = enumerate_catalog_pairs(cat)
    assert cells == cat.tiles.shape[0] * geom[0] * geom[1]
    assert live == ea.size == job.total_pairs
    assert waste == cells - ea.size


def test_every_lattice_candidate_fits_vmem_double_buffered():
    """Mask path and bounded-capacity compact path fit the budget for
    every lattice candidate at d=256; unbounded capacity on the largest
    tiles legitimately does not (the lowering guard catches it)."""
    for bm, bn in GEOMETRY_LATTICE:
        assert catalog_vmem_bytes(bm, bn, 256) <= VMEM_BUDGET_BYTES, (bm, bn)
        check_vmem(bm, bn, 256, capacity=1024)  # shipped serving capacity
    assert catalog_vmem_bytes(64, 256, 256, capacity=64 * 256) \
        > VMEM_BUDGET_BYTES


def test_check_vmem_rejects_oversized_working_set():
    with pytest.raises(ValueError, match="VMEM"):
        check_vmem(1024, 1024, 4096)


def test_autotune_raises_when_nothing_fits():
    with pytest.raises(ValueError):
        autotune(_JOBS["block_split"], d=100_000)


def test_autotune_prefers_occupancy_on_skew():
    """At s=1.0 skew the fixed 128×128 tile is mostly dead cells — the
    static pick must beat it on occupancy AND model cost."""
    rep = autotune(_JOBS["block_split"], d=D)
    assert rep.geometry != (128, 128)
    by_geom = {s.geometry: s for s in rep.scores}
    best, base = by_geom[rep.geometry], by_geom[(128, 128)]
    assert best.occupancy > base.occupancy
    assert best.model_cost < base.model_cost
    assert best.live_pairs == base.live_pairs  # geometry-invariant


def test_autotune_feedback_overrides_static_ranking():
    """One measured rate anywhere wall-clock-anchors the lattice; a
    measured-fast geometry must win over the static favourite."""
    job = _JOBS["block_split"]
    static = autotune(job, d=D)
    loser = next(s for s in static.scores if s.geometry != static.geometry)
    fb = GeometryCostModel()
    fb.observe(static.geometry, 1e6, 10.0)   # static pick measured slow
    fb.observe(loser.geometry, 1e6, 0.1)     # runner-up measured fast
    refit = autotune(job, d=D, feedback=fb)
    assert refit.geometry == loser.geometry
    assert refit.measured


def test_geometry_cost_model_state_roundtrip():
    fb = GeometryCostModel()
    fb.observe((64, 64), 1e6, 0.5)
    fb.observe((32, 32), 2e6, 0.4)
    clone = GeometryCostModel.from_state(fb.to_state())
    for g in ((64, 64), (32, 32)):
        assert clone.rate(g) == fb.rate(g)
    assert clone.best() == fb.best() == (32, 32)
    assert np.isnan(clone.rate((256, 256)))
    with pytest.raises(ValueError):
        GeometryCostModel.from_state({"version": 99})


def test_ewma_cost_model_state_roundtrip():
    m = EwmaCostModel(n_dev=3)
    rng = np.random.default_rng(0)
    from repro.er.compiler.feedback import N_TILE_CLASSES
    for dev in range(3):
        m.observe(dev, rng.uniform(1, 9, N_TILE_CLASSES), rng.uniform(.1, 2))
    clone = EwmaCostModel.from_state(m.to_state())
    for dev in range(3):
        assert clone.rate(dev) == pytest.approx(m.rate(dev), nan_ok=True)
        for c in range(N_TILE_CLASSES):
            assert clone.rate(dev, c) == pytest.approx(m.rate(dev, c),
                                                       nan_ok=True)
    assert clone.observations == m.observations
    with pytest.raises(ValueError):
        EwmaCostModel.from_state({"version": 0})


def _service_cfg():
    return ServiceConfig(feature_dim=64, max_len=48, r=8, m=4,
                         query_buckets=(8,), tile_chunk=64,
                         autotune_tiles=True,
                         autotune_lattice=((32, 32), (64, 64)))


def test_service_warm_start_skips_sweep():
    """A service seeded with an exported feedback state skips the warmup
    geometry sweep: fewer compiles, same pinned geometry, and it serves
    the exact same answers as the cold service."""
    titles = make_products(300, seed=3).titles
    cold = ERService(titles, _service_cfg())
    with compile_counter() as cc_cold:
        cold.warmup()
    state = cold.export_feedback_state()
    assert cold.tune_report is not None
    assert state["geometry"]["rates"], "sweep left no measured rates"

    cfg = _service_cfg()
    cfg.feedback_state = state
    warm = ERService(titles, cfg)
    assert warm.geometry_feedback.best(cfg.autotune_lattice) is not None
    with compile_counter() as cc_warm:
        warm.warmup()
    assert warm.tile_geometry == cold.tile_geometry
    assert cc_warm.count < cc_cold.count, \
        (cc_warm.count, cc_cold.count)
    # and the warm service serves the same answers
    qs = titles[:8]
    assert set(warm.match(qs)) == set(cold.match(qs))


def test_mesh_compact_path_decodes_on_device():
    """The mesh execution path decodes stage-1 survivors from the packed
    epilogue — compact_decodes increments, nonzero_decodes does not."""
    try:
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:
        mesh = jax.make_mesh((1,), ("data",))
    cat = lower(_JOBS["block_split"], 64, 64)
    before = dict(stage1_stats)
    ra, rb = execute(cat, FEATS, threshold=THRESHOLD, impl="xla",
                     mesh=mesh, chunk_tiles=64)
    got = {(min(a, b), max(a, b)) for a, b in zip(ra.tolist(), rb.tolist())}
    assert got == _REF["block_split"]
    assert stage1_stats["compact_decodes"] > before["compact_decodes"]
    assert stage1_stats["nonzero_decodes"] == before["nonzero_decodes"]
