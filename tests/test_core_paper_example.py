"""The paper's running example (Figs. 3-7) as ground truth.

Reconstruction of Fig. 3 from the paper's own printed numbers (the figure
itself is an image we cannot read, but the text pins it down):

  * 14 entities A-O (J unused), two partitions of 7 (§III-B).
  * P = 20 pairs; largest block z has 5 entities (35% of 14) and 10 pairs
    (50% of 20) (§III-B).
  * Block order w,x,y,z = Φ0..Φ3 ("we assign the first block (key w) to
    block index position 0").
  * "the index for pair (2,3) of block Φ0 equals 5" → c(2,3,N0)=5 → N0=4,
    so |w| = 4.
  * BlockSplit task ordering "0.*, 3.0×1, 2.*, 3.1, 1.*, 3.0" (§IV) with
    task sizes descending forces |Φ2|=|y|=3 (3 pairs) and |Φ1|=|x|=2
    (1 pair): sizes (4,2,3,5) → pairs (6,1,3,10), Σ=20. ✓
  * "Π0 and Π1 contain 2 and 3 entities" of z (§IV); Φ3 = {F,G,M,N,O}
    with M "the third entity of Φ3" → F,G ∈ Π0; M,N,O ∈ Π1 (§V, Fig. 7).
  * M's pairs print as 11, 14, 17, 18 and ranges ℜ0=[0,6], ℜ1=[7,13],
    ℜ2=[14,19] — all reproduced exactly below with o = [0,6,7,10].

The per-partition splits of w, x, y are not printed; we use the unique
choice consistent with 7 + 7 entities: w=[2,2], x=[1,1], y=[2,1].
Everything asserted below is a number printed in the paper's text.
"""
import numpy as np
import pytest

from repro.core import (
    compute_bdm, entity_indices, blocked_layout,
    plan_basic, plan_block_split, plan_pair_range,
    pairs_of_range, entity_range_matrix, enumeration as en,
)

BLOCK_OF = dict(w=0, x=1, y=2, z=3)  # Φ0..Φ3 (Fig. 4 row order)
P0 = ["A.w", "B.x", "C.y", "D.w", "E.y", "F.z", "G.z"]
P1 = ["H.w", "I.x", "K.y", "L.w", "M.z", "N.z", "O.z"]


def example():
    names, blocks, parts = [], [], []
    for pidx, part in enumerate([P0, P1]):
        for item in part:
            name, key = item.split(".")
            names.append(name)
            blocks.append(BLOCK_OF[key])
            parts.append(pidx)
    return names, np.array(blocks), np.array(parts)


def test_bdm_matches_paper():
    _, blocks, parts = example()
    bdm = compute_bdm(blocks, parts, 4, 2)
    # §IV: z has 2 entities in Π0 and 3 in Π1 → row [2, 3]; "outputs
    # [z,1,3] because there are 3 entities in the second partition".
    expected = np.array([[2, 2], [1, 1], [2, 1], [2, 3]])
    np.testing.assert_array_equal(bdm, expected)
    np.testing.assert_array_equal(bdm.sum(axis=1), [4, 2, 3, 5])


def test_block_pair_counts():
    _, blocks, parts = example()
    bdm = compute_bdm(blocks, parts, 4, 2)
    sizes = bdm.sum(axis=1)
    pairs = en.block_pair_counts(sizes)
    # §III-B: block sizes 2..5; pair counts 1..10; z = 50% of P=20 pairs
    # while holding only 35% (5/14) of entities.
    np.testing.assert_array_equal(sizes, [4, 2, 3, 5])
    np.testing.assert_array_equal(pairs, [6, 1, 3, 10])
    assert pairs.sum() == 20
    assert pairs.max() / pairs.sum() == 0.5
    assert sizes.max() / sizes.sum() == pytest.approx(5 / 14)


def test_entity_indices_match_fig6():
    names, blocks, parts = example()
    bdm = compute_bdm(blocks, parts, 4, 2)
    idx = entity_indices(blocks, parts, bdm)
    by_name = dict(zip(names, idx))
    # §V: "M is the first entity of block Φ3 in partition Π1 ... there are
    # two other entities in Φ3 in the preceding partition Π0 → M is the
    # third entity of Φ3 and is thus assigned entity index 2."
    assert by_name["M"] == 2
    assert by_name["F"] == 0 and by_name["G"] == 1
    assert by_name["N"] == 3 and by_name["O"] == 4
    # Blocks enumerate partition-major: A, D (Π0) then H, L (Π1).
    assert by_name["A"] == 0 and by_name["D"] == 1
    assert by_name["H"] == 2 and by_name["L"] == 3


def test_cell_index_fig6_values():
    # "the index for pair (2,3) of block Φ0 equals 5": c(2,3,4) = 5.
    assert en.cell_index(2, 3, 4) == 5
    # M (§V): N=5, x=2 → p_min = c(0,2,5)+o(3) = 1+10 = 11,
    # p_max = c(2,4,5)+o(3) = 8+10 = 18. Paper prints exactly 11 and 18.
    assert en.cell_index(0, 2, 5) == 1
    assert en.cell_index(2, 4, 5) == 8


def test_pair_offsets_and_m_pairs():
    sizes = np.array([4, 2, 3, 5], np.int64)
    pairs = en.block_pair_counts(sizes)
    offsets, total = en.pair_offsets(pairs)
    assert total == 20  # "we have P = 20 pairs"
    np.testing.assert_array_equal(offsets, [0, 6, 7, 10])
    # M takes part in pairs 11, 14, 17, 18 (§V, Fig. 7).
    blk = np.int64(3)
    m_pairs = [int(en.pair_index(blk, np.int64(x), np.int64(y), sizes, offsets))
               for x, y in [(0, 2), (1, 2), (2, 3), (2, 4)]]
    assert m_pairs == [11, 14, 17, 18]


def test_pair_index_roundtrip_paper_world():
    sizes = np.array([4, 2, 3, 5], np.int64)
    offsets, total = en.pair_offsets(en.block_pair_counts(sizes))
    p = np.arange(total, dtype=np.int64)
    blk, x, y = en.invert_pair_index(p, sizes, offsets)
    p2 = en.pair_index(blk, x, y, sizes, offsets)
    np.testing.assert_array_equal(p, p2)
    assert (x < y).all()
    assert (y < sizes[blk]).all()


def test_pair_ranges_fig7():
    sizes = np.array([4, 2, 3, 5], np.int64)
    _, total = en.pair_offsets(en.block_pair_counts(sizes))
    bounds = en.range_bounds(total, 3)
    # "ℜ0 = [0,6], ℜ1 = [7,13], ℜ2 = [14,19]" (inclusive in the paper).
    np.testing.assert_array_equal(bounds, [[0, 7], [7, 14], [14, 20]])


def test_block_split_fig5():
    """Fig. 5: only Φ3 (z) splits; match tasks 3.0 (1 pair), 3.0×1 (6),
    3.1 (3); ordering 0.*, 3.0×1, 2.*, 3.1, 1.*, 3.0; 19 kv-pairs emitted;
    'each reduce task has to process between six and seven comparisons'."""
    _, blocks, parts = example()
    bdm = compute_bdm(blocks, parts, 4, 2)
    plan = plan_block_split(bdm, r=3)
    assert plan.total_pairs == 20
    # avg = 20/3 ≈ 6.67; only z (10 pairs) exceeds it.
    np.testing.assert_array_equal(plan.split_mask, [False, False, False, True])

    tasks = {}
    for t in range(plan.task_block.shape[0]):
        key = (int(plan.task_block[t]), int(plan.task_i[t]), int(plan.task_j[t]))
        tasks[key] = int(plan.task_pairs[t])
    assert tasks[(3, 0, 0)] == 1    # 3.0: sub-block of 2 entities
    assert tasks[(3, 1, 0)] == 6    # 3.0×1: 2*3
    assert tasks[(3, 1, 1)] == 3    # 3.1: sub-block of 3 entities
    assert tasks[(0, -1, -1)] == 6  # 0.*
    assert tasks[(1, -1, -1)] == 1  # 1.*
    assert tasks[(2, -1, -1)] == 3  # 2.*

    # Descending task order matches the paper's print:
    # 0.*(6), 3.0×1(6), 2.*(3), 3.1(3), 1.*(1), 3.0(1).
    order = np.argsort(-plan.task_pairs, kind="stable")
    ordered = [(int(plan.task_block[t]), int(plan.task_i[t]), int(plan.task_j[t]))
               for t in order]
    assert ordered == [(0, -1, -1), (3, 1, 0), (2, -1, -1),
                       (3, 1, 1), (1, -1, -1), (3, 0, 0)]

    # Fig. 5: replication of the 5 split-block entities → 14 + 5 = 19.
    assert plan.map_output_size() == 19
    # Greedy LPT loads: {7, 7, 6}.
    assert plan.reducer_pairs.sum() == 20
    assert sorted(plan.reducer_pairs.tolist()) == [6, 7, 7]


def test_basic_plan():
    _, blocks, parts = example()
    bdm = compute_bdm(blocks, parts, 4, 2)
    plan = plan_basic(bdm, r=3)
    assert plan.total_pairs == 20
    assert plan.map_output_size() == 14  # no replication
    # Basic's makespan is lower-bounded by the largest block (10 pairs).
    assert plan.reducer_pairs.max() >= 10


def test_pair_range_plan_and_materialization():
    _, blocks, parts = example()
    bdm = compute_bdm(blocks, parts, 4, 2)
    plan = plan_pair_range(bdm, r=3)
    assert plan.total_pairs == 20
    np.testing.assert_array_equal(plan.bounds, [[0, 7], [7, 14], [14, 20]])
    seen = set()
    for k in range(3):
        blk, x, y, ra, rb = pairs_of_range(plan, k)
        assert (x < y).all()
        for t in zip(blk, x, y):
            seen.add(tuple(int(v) for v in t))
    assert len(seen) == 20  # every pair exactly once


def test_entity_range_matrix_covers_m_and_f():
    """§V/Fig. 7: M goes to reducers 1 and 2 only; the third reducer
    receives all of Φ3 but F."""
    names, blocks, parts = example()
    bdm = compute_bdm(blocks, parts, 4, 2)
    idx = entity_indices(blocks, parts, bdm)
    plan = plan_pair_range(bdm, r=3)
    mask = entity_range_matrix(plan)
    perm, estart = blocked_layout(blocks, idx, plan.block_sizes)
    # M: Π1[4] → source row 7+4 = 11; blocked row estart[3]+2 = 9+2 = 11.
    m_row = int(estart[3] + 2)
    assert perm[m_row] == 11
    # M's pairs 11,14,17,18 → ranges (per=7): 1, 2, 2, 2.
    np.testing.assert_array_equal(mask[m_row], [False, True, True])
    # F (block 3, x=0): pairs 10..13 all in ℜ1 → not sent to ℜ2.
    f_row = int(estart[3] + 0)
    np.testing.assert_array_equal(mask[f_row], [False, True, False])
    # Reducer 2 receives G, M, N, O of Φ3 (everything but F).
    phi3_rows = np.arange(estart[3], estart[3] + 5)
    np.testing.assert_array_equal(mask[phi3_rows, 2], [False, True, True, True, True])


@pytest.mark.parametrize("n", [2, 3, 5, 17, 128, 1000])
def test_invert_cell_index_bruteforce(n):
    q = np.arange(n * (n - 1) // 2, dtype=np.int64)
    x, y = en.invert_cell_index(q, np.int64(n))
    ref = [(a, b) for a in range(n) for b in range(a + 1, n)]
    ref.sort(key=lambda t: en.cell_index(t[0], t[1], n))
    np.testing.assert_array_equal(x, [t[0] for t in ref])
    np.testing.assert_array_equal(y, [t[1] for t in ref])
