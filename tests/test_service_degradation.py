"""Service-level graceful degradation (DESIGN.md §Fault tolerance):
``ERService.match`` over the supervised executor survives chaos — kills
recover to the exact quiet match set, repeatedly-failing devices are
circuit-broken and re-admitted after a probe succeeds, an exhausted
request deadline or retry budget degrades to partial results with
``coverage < 1`` instead of failing, and a fully-broken service raises
the typed :class:`ServiceUnavailable` with retry-after semantics."""
import threading
import time

import numpy as np
import pytest

from repro.er import (ERService, MatchResponse, ServiceConfig,
                      ServiceUnavailable, make_products)
from repro.er.compiler import FaultEvent, FaultInjector, FaultScript

DS = make_products(250, seed=3)
CORPUS = DS.titles[:140]
QUERIES = DS.titles[140:170]


def _cfg(**kw):
    base = dict(feature_dim=128, max_len=48, r=8, m=4,
                query_buckets=(8, 32), tile_chunk=64)
    base.update(kw)
    return ServiceConfig(**base)


def _quiet_answers(batches):
    svc = ERService(CORPUS, _cfg())
    return [set(svc.match(b)) for b in batches]


def test_supervised_quiet_path_equals_unsupervised():
    batches = [QUERIES[:6], QUERIES[6:14], QUERIES[14:22]]
    want = _quiet_answers(batches)
    svc = ERService(CORPUS, _cfg(exec_devices=4))
    for batch, w in zip(batches, want):
        resp = svc.match(batch)
        assert isinstance(resp, MatchResponse) and isinstance(resp, set)
        assert set(resp) == w
        assert resp.coverage == 1.0 and resp.attempts == 1
        assert not resp.degraded
    assert svc.stats["retries"] == 0
    assert svc.stats["breaker_evictions"] == 0


def test_chaos_kills_recover_to_exact_match_set():
    batches = [QUERIES[:8], QUERIES[8:16], QUERIES[16:24], QUERIES[:8]]
    want = _quiet_answers(batches)
    svc = ERService(CORPUS, _cfg(exec_devices=4, backoff_s=0.0,
                                 breaker_threshold=2,
                                 breaker_cooldown_s=1e9))
    svc.set_fault_injector(FaultInjector(FaultScript(events=(
        FaultEvent("kill", 1, 0),
        FaultEvent("corrupt", 2, 2),
        FaultEvent("transient", 3, 5),
        FaultEvent("kill", 2, 7)), n_dev=4)))
    for batch, w in zip(batches, want):
        resp = svc.match(batch)
        assert set(resp) == w                 # full recovery, every batch
        assert resp.coverage == 1.0 and not resp.degraded
    assert svc.stats["retries"] > 0
    assert svc.stats["recovered_tiles"] > 0
    assert svc.stats["degraded"] == 0
    # dead devices kept failing → the breaker took them out of rotation
    assert svc.stats["breaker_evictions"] >= 1


def test_breaker_opens_then_service_unavailable():
    svc = ERService(CORPUS, _cfg(exec_devices=2, backoff_s=0.0,
                                 breaker_threshold=1,
                                 breaker_cooldown_s=1e9))
    svc.set_fault_injector(FaultInjector(FaultScript(events=(
        FaultEvent("kill", 0, 0), FaultEvent("kill", 1, 0)), n_dev=2)))
    resp = svc.match(QUERIES[:6])             # everything dies mid-job →
    assert resp.degraded and resp.coverage < 1.0   # partial, not a crash
    assert len(resp) == 0
    assert svc.stats["breaker_evictions"] == 2
    with pytest.raises(ServiceUnavailable) as ei:  # breaker fully open
        svc.match(QUERIES[:6])
    assert ei.value.retry_after_s > 0


def test_all_devices_dead_without_partial_is_typed_error():
    svc = ERService(CORPUS, _cfg(exec_devices=2, backoff_s=0.0,
                                 partial_results=False))
    svc.set_fault_injector(FaultInjector(FaultScript(events=(
        FaultEvent("kill", 0, 0), FaultEvent("kill", 1, 0)), n_dev=2)))
    with pytest.raises(ServiceUnavailable) as ei:
        svc.match(QUERIES[:6])                # clean retry-after, no
    assert ei.value.retry_after_s > 0         # traceback soup for clients


def test_breaker_probe_readmits_after_revive():
    want = _quiet_answers([QUERIES[:6]])[0]
    svc = ERService(CORPUS, _cfg(exec_devices=2, backoff_s=0.0,
                                 breaker_threshold=1,
                                 breaker_cooldown_s=0.0))
    svc.set_fault_injector(FaultInjector(FaultScript(events=(
        FaultEvent("kill", 1, 0), FaultEvent("revive", 1, 10)), n_dev=2)))
    for _ in range(8):                        # serve until a probe lands
        assert set(svc.match(QUERIES[:6])) == want
    assert svc.stats["breaker_evictions"] >= 1
    assert svc.stats["breaker_readmissions"] >= 1
    assert not svc._breaker_open              # device 1 back in rotation


def test_request_deadline_degrades_to_partial():
    svc = ERService(CORPUS, _cfg(exec_devices=2, request_deadline_s=0.0))
    resp = svc.match(QUERIES[:6])
    assert resp.degraded and resp.coverage < 1.0
    assert len(resp) == 0                     # nothing scored in 0 seconds
    assert svc.stats["degraded"] == 1


def test_retry_exhaustion_degrades_to_partial_coverage():
    svc = ERService(CORPUS, _cfg(exec_devices=1, max_retries=1,
                                 backoff_s=0.0))
    svc.set_fault_injector(FaultInjector(FaultScript(events=tuple(
        FaultEvent("corrupt", 0, 0) for _ in range(100)), n_dev=1)))
    resp = svc.match(QUERIES[:6])             # every round corrupts →
    assert resp.degraded and resp.coverage < 1.0   # survivors kept anyway
    assert resp.attempts == 2                 # 1 round + max_retries


def test_match_response_behaves_like_the_historical_set():
    svc = ERService(CORPUS, _cfg())
    resp = svc.match(QUERIES[:4])
    assert resp == set(resp)                  # plain-set equality
    assert (resp | {(0, 99)}) >= resp         # set algebra still works
    empty = svc.match([])
    assert isinstance(empty, MatchResponse) and len(empty) == 0
    assert empty.coverage == 1.0 and not empty.degraded


def test_oversized_request_spends_one_shared_deadline():
    """REGRESSION (PR 8): ``match`` used to arm a FRESH request deadline
    for every top-bucket slice of an oversized batch, so a k-slice
    request under chaos could stall ~k deadlines before degrading. The
    deadline is armed once at the outer entry now — all slices spend one
    shared budget, and wall time is bounded by ~one deadline."""
    deadline = 0.6
    svc = ERService(CORPUS, _cfg(query_buckets=(8,), exec_devices=2,
                                 request_deadline_s=deadline,
                                 backoff_s=30.0,
                                 breaker_threshold=10_000))
    svc.warmup()                              # compiles outside the timer
    # endless transient storm: every shard call fails, every retry wants
    # a 30 s backoff — only the request deadline bounds the request
    svc.set_fault_injector(FaultInjector(FaultScript(events=tuple(
        FaultEvent("transient", d, 0) for d in (0, 1) for _ in range(400)),
        n_dev=2)))
    t0 = time.perf_counter()
    resp = svc.match(QUERIES[:24])            # 3 slices of the 8-bucket
    wall = time.perf_counter() - t0
    assert resp.degraded and resp.coverage < 1.0
    assert wall >= 0.5 * deadline             # the budget WAS spent once…
    assert wall < 2.0 * deadline              # …not once per slice (≥ 3×)


def test_concurrent_requests_equal_sequential_exactly():
    """REGRESSION (PR 8): request-scoped state (deadline, supervised
    reports) lived on the service instance, so overlapping requests from
    different threads clobbered each other's budgets and coverage
    accounting. It lives on a per-request context now: concurrent calls
    return exactly the sequential match sets with clean metadata."""
    batches = [QUERIES[:8], QUERIES[8:16], QUERIES[16:24], QUERIES[24:30]]
    want = _quiet_answers(batches)
    svc = ERService(CORPUS, _cfg(exec_devices=2))
    errors = []

    def worker(idx):
        try:
            for _ in range(5):
                for batch, w in zip(batches[idx::2], want[idx::2]):
                    resp = svc.match(batch)
                    assert set(resp) == w
                    assert resp.coverage == 1.0 and not resp.degraded
        except BaseException as e:            # surfaced on the main thread
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert svc.stats["batches"] == 2 * 5 * 2
    assert svc.stats["degraded"] == 0


def test_supervised_refuses_mesh():
    class FakeMesh:
        shape = {"data": 1}

    with pytest.raises(ValueError):
        ERService(CORPUS[:10], _cfg(exec_devices=2),
                  mesh=FakeMesh(), axis="data")
