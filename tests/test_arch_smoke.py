"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step + one prefill/decode round on CPU; asserts
output shapes and no NaNs. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import get_model
from repro.train import adamw_init, make_train_step
from repro.train.optimizer import AdamWConfig

B, S = 2, 16


def _batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = reduced(ARCHS[arch])
    rng = np.random.default_rng(0)
    mod = get_model(cfg)
    params = mod.init(cfg, jax.random.key(0))

    batch = _batch(cfg, rng)
    logits = mod.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in forward logits"

    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    opt_state = adamw_init(params, AdamWConfig(lr=1e-3))
    params2, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), "NaN loss"
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    cfg = reduced(ARCHS[arch])
    rng = np.random.default_rng(1)
    mod = get_model(cfg)
    params = mod.init(cfg, jax.random.key(1))
    batch = _batch(cfg, rng)

    logits_full = mod.forward(params, batch, cfg)
    prefix = cfg.n_patches if cfg.family == "vlm" else 0
    cache = mod.init_cache(cfg, B, S + prefix + 4)
    last, cache = mod.prefill(params, batch, cfg, cache)
    assert bool(jnp.isfinite(last).all())
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), atol=2e-3, rtol=1e-2)

    nxt = jnp.argmax(last[:, -1:], axis=-1)
    step_logits, cache = mod.decode_step(params, nxt, cache, cfg)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    full2 = mod.forward(params, b2, cfg)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full2[:, -1], np.float32), atol=2e-3, rtol=1e-2)


def test_moe_grouped_dispatch_matches_gshard():
    cfg = reduced(ARCHS["qwen3-moe-235b-a22b"])
    from repro.models import moe as moe_mod
    rng = np.random.default_rng(2)
    params = moe_mod.init(cfg, jax.random.key(2))
    p1 = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.asarray(rng.standard_normal((B * S, cfg.d_model)), jnp.float32)
    w, ids, _ = moe_mod._route(p1, x, cfg)
    y1 = moe_mod._experts_gshard(p1, x, w, ids, cfg)
    y2 = moe_mod._experts_grouped(p1, x, w, ids, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_generate_runs():
    from repro.serve import generate

    cfg = reduced(ARCHS["smollm-360m"])
    mod = get_model(cfg)
    params = mod.init(cfg, jax.random.key(3))
    rng = np.random.default_rng(3)
    out = generate(params, cfg, _batch(cfg, rng), max_new_tokens=5)
    assert out.shape == (B, 5)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())
