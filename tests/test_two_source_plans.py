"""Two-source plan oracles (paper Appendix I): brute-force R × S pair
enumeration per block vs ``plan_block_split_2src`` /
``plan_pair_range_2src`` — coverage, disjointness, row-mapping, and the
paper's imbalance bounds — on hypothesis-generated skewed BDMs, plus the
cross-tile catalog compilers that wire these plans into the executor.
(Closes the gap where only ``test_two_source_plans_cover`` existed.)
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.two_source import (TwoSourceBDM, pairs_of_range_2src,
                                   plan_block_split_2src,
                                   plan_pair_range_2src,
                                   range_block_segments_2src)
from repro.er.executor import (catalog_for_two_source,
                               enumerate_catalog_pairs, pad_catalog_tiles)


@st.composite
def skewed_bdm2(draw):
    """Per-source BDMs over a shared block space, skewed: a few dominant
    blocks, zero-size blocks on either side, uneven partition counts."""
    b = draw(st.integers(1, 12))
    m_r = draw(st.integers(1, 4))
    m_s = draw(st.integers(1, 3))
    rows_r, rows_s = [], []
    for k in range(b):
        shape = draw(st.sampled_from(["zero_r", "zero_s", "small", "big"]))
        big = draw(st.integers(20, 60))
        if shape == "zero_r":
            rows_r.append([0] * m_r)
            rows_s.append([draw(st.integers(0, 6)) for _ in range(m_s)])
        elif shape == "zero_s":
            rows_r.append([draw(st.integers(0, 6)) for _ in range(m_r)])
            rows_s.append([0] * m_s)
        elif shape == "big":
            rows_r.append([big] + [draw(st.integers(0, 4))] * (m_r - 1))
            rows_s.append([draw(st.integers(1, 30))] + [0] * (m_s - 1))
        else:
            rows_r.append([draw(st.integers(0, 4)) for _ in range(m_r)])
            rows_s.append([draw(st.integers(0, 4)) for _ in range(m_s)])
    return TwoSourceBDM(bdm_r=np.asarray(rows_r, np.int64),
                        bdm_s=np.asarray(rows_s, np.int64))


def _brute_pairs(bdm2):
    """All cross-source cells (block, x, y) and their global rows."""
    sr, ss = bdm2.sizes_r, bdm2.sizes_s
    er = np.concatenate([[0], np.cumsum(sr)[:-1]])
    es = np.concatenate([[0], np.cumsum(ss)[:-1]])
    cells, rows = set(), set()
    for k in range(sr.shape[0]):
        for x in range(int(sr[k])):
            for y in range(int(ss[k])):
                cells.add((k, x, y))
                rows.add((int(er[k] + x), int(es[k] + y)))
    return cells, rows, er, es


@given(skewed_bdm2(), st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_pair_range_2src_partitions_and_balance(bdm2, r):
    plan = plan_pair_range_2src(bdm2, r)
    cells, rows, _, _ = _brute_pairs(bdm2)
    assert plan.total_pairs == len(cells)
    seen_cells, seen_rows = set(), set()
    for k in range(r):
        blk, x, y, rr, rs = pairs_of_range_2src(plan, k)
        assert rr.shape == (int(plan.reducer_pairs[k]),)
        for t, rt in zip(zip(blk.tolist(), x.tolist(), y.tolist()),
                         zip(rr.tolist(), rs.tolist())):
            assert t not in seen_cells          # disjoint
            seen_cells.add(t)
            seen_rows.add(rt)
    assert seen_cells == cells                  # exhaustive
    assert seen_rows == rows                    # row mapping exact
    # Alg. 2's ceil split: perfectly balanced by construction.
    if plan.total_pairs:
        assert int(plan.reducer_pairs.max()) == -(-plan.total_pairs // r)


@given(skewed_bdm2(), st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_block_split_2src_covers_and_lpt_bound(bdm2, r):
    plan = plan_block_split_2src(bdm2, r)
    cells, rows, er, es = _brute_pairs(bdm2)
    assert plan.total_pairs == len(cells)
    assert int(plan.reducer_pairs.sum()) == len(cells)
    got_rows = set()
    for t in range(plan.task_block.shape[0]):
        a0, al = int(plan.task_a_start[t]), int(plan.task_a_len[t])
        b0, bl = int(plan.task_b_start[t]), int(plan.task_b_len[t])
        assert al * bl == int(plan.task_pairs[t])
        for i in range(al):
            for j in range(bl):
                p = (a0 + i, b0 + j)
                assert p not in got_rows        # disjoint tasks
                got_rows.add(p)
    assert got_rows == rows                     # exhaustive
    # Paper's bound: greedy LPT keeps makespan within (4/3 − 1/3r)·OPT,
    # OPT >= max(P/r, largest match task).
    if plan.total_pairs:
        w_max = int(plan.task_pairs.max())
        opt_lb = max(plan.total_pairs / r, w_max)
        assert int(plan.reducer_pairs.max()) <= \
            (4 / 3 - 1 / (3 * r)) * opt_lb + 1e-9


@given(skewed_bdm2(), st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_range_segments_2src_match_materialization(bdm2, r):
    """The O(1)-per-(range, block) segment decomposition enumerates the
    same cells as the per-pair materialization."""
    plan = plan_pair_range_2src(bdm2, r)
    for k in range(r):
        blk, x, y, _, _ = pairs_of_range_2src(plan, k)
        want = set(zip(blk.tolist(), x.tolist(), y.tolist()))
        got = set()
        for sblk, x_lo, y_lo, x_hi, y_hi in range_block_segments_2src(plan, k):
            ns = int(plan.sizes_s[sblk])
            for q in range(x_lo * ns + y_lo, x_hi * ns + y_hi + 1):
                cell = (sblk, q // ns, q % ns)
                assert cell not in got
                got.add(cell)
        assert got == want


@pytest.mark.parametrize("planner", (plan_pair_range_2src,
                                     plan_block_split_2src))
@pytest.mark.parametrize("bm,bn", [(16, 16), (16, 32)])
def test_two_source_catalog_covers_plan_exactly(planner, bm, bn):
    """Every planned R × S pair appears in the cross-tile catalog exactly
    once — unaligned strips, zero blocks, dominant blocks; padding with
    zero entries adds nothing."""
    rng = np.random.default_rng(9)
    for _ in range(8):
        b = int(rng.integers(1, 9))
        bdm2 = TwoSourceBDM(
            bdm_r=rng.integers(0, 40, (b, int(rng.integers(1, 4)))),
            bdm_s=rng.integers(0, 25, (b, int(rng.integers(1, 3)))))
        if b > 1:
            bdm2.bdm_r[int(rng.integers(0, b))] = 0
        plan = planner(bdm2, int(rng.integers(1, 7)))
        cat = pad_catalog_tiles(catalog_for_two_source(plan, bm, bn), 32)
        assert cat.tiles.shape[0] % 32 == 0
        ea, eb = enumerate_catalog_pairs(cat)
        got = list(zip(ea.tolist(), eb.tolist()))
        assert len(got) == len(set(got))
        _, rows, _, _ = _brute_pairs(bdm2)
        assert set(got) == rows
        assert cat.total_pairs == len(rows)
        assert cat.n_rows_a == int(bdm2.sizes_r.sum())
        assert cat.n_rows_b == int(bdm2.sizes_s.sum())


def test_pair_range_2src_catalog_respects_ranges():
    """Each reducer's tiles cover exactly its own range's cells."""
    rng = np.random.default_rng(4)
    bdm2 = TwoSourceBDM(bdm_r=rng.integers(0, 30, (7, 2)),
                        bdm_s=rng.integers(0, 20, (7, 2)))
    plan = plan_pair_range_2src(bdm2, 5)
    from repro.er.executor import RED, TileCatalog
    cat = catalog_for_two_source(plan, 16, 16)
    for k in range(plan.r):
        sub = cat.tiles[cat.tiles[:, RED] == k]
        ea, eb = enumerate_catalog_pairs(TileCatalog(
            tiles=sub, block_m=16, block_n=16, n_rows_a=cat.n_rows_a,
            n_rows_b=cat.n_rows_b, r=plan.r, total_pairs=0))
        _, _, _, rr, rs = pairs_of_range_2src(plan, k)
        assert set(zip(ea.tolist(), eb.tolist())) == \
            set(zip(rr.tolist(), rs.tolist()))
