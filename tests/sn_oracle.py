"""Windowed-pair brute-force oracle for Sorted Neighborhood tests.

Enumerates the window-w band directly — one numpy diagonal per sort-order
distance d ∈ [1, w), O(n·w) pairs total — with no planner, catalog, or
kernel machinery involved, then applies the pipeline's two-stage match
(numpy cosine filter at threshold − margin, exact edit-distance verify at
threshold via the shared ``verify_pairs`` primitive — the enumeration and
stage-1 filter are the parts under test; stage 2 is the same exact
verifier every path shares). The parity suite asserts
``run_er(strategy="sorted_neighborhood")`` produces exactly this set.
"""
import numpy as np

from repro.er.blocking import sn_sort_order
from repro.er.encode import encode_titles, ngram_features
from repro.er.executor import verify_pairs


def sn_oracle_matches(titles, w, *, threshold=0.8, filter_margin=0.25,
                      feature_dim=256, max_len=64):
    """The exact SN match set as {(i, j), i < j} original-index pairs."""
    order = sn_sort_order(titles)
    codes, lens = encode_titles(titles, max_len=max_len)
    feats = ngram_features(codes, dim=feature_dim, lengths=lens)
    f, c, l = feats[order], codes[order], lens[order]
    n = len(titles)
    cand_a, cand_b = [], []
    for d in range(1, min(w, n)):                 # one band diagonal at a time
        a = np.arange(0, n - d, dtype=np.int64)
        b = a + d
        cos = np.einsum("pd,pd->p", f[a], f[b])
        sel = np.flatnonzero(cos >= threshold - filter_margin)
        cand_a.append(a[sel])
        cand_b.append(b[sel])
    ca = np.concatenate(cand_a) if cand_a else np.zeros(0, np.int64)
    cb = np.concatenate(cand_b) if cand_b else np.zeros(0, np.int64)
    ha, hb = verify_pairs(c, l, c, l, ca, cb, threshold)
    matches = set()
    for i, j in zip(ha, hb):
        ga, gb = int(order[i]), int(order[j])
        matches.add((min(ga, gb), max(ga, gb)))
    return matches


def sn_band_pairs_bruteforce(n, w):
    """Every band pair as a set {(i, j)} over sorted positions, O(n·w)."""
    return {(i, j) for i in range(n) for j in range(i + 1, min(i + w, n))}
