"""2-D mesh comms policies: planner unit tests + exact-parity sweeps.

Host-side tests cover the pure planner (`compiler.comms`): hop/byte
accounting, locality placement, alignment gates and pinned-hop
fallbacks, and the buffer-local tile rewrite.

Subprocess tests (the device count must be pinned before jax
initializes) assert the load-bearing contract: the ring and
hierarchical strip exchanges, with and without a model axis, produce
EXACTLY the flat all-gather's stage-1 survivor set — on non-square
device grids, under both the mask and compact epilogues — and the
multi-hop halo executor reproduces the brute-force SN oracle at
windows wider than a shard (w − 1 > n / n_dev)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.er.compiler import (CommsPlan, comms_volume, default_group,
                               halo_bytes_per_device, halo_hop_rows, lower,
                               plan_comms, plan_to_job,
                               psum_bytes_per_device, rewrite_tiles_local)
from repro.er.compiler.ir import A_TILE, R0, R1, NCOLS
from repro.core import compute_bdm, plan_pair_range


def _blocked_catalog(n=1024, n_blocks=16, r=8, bm=64, bn=64, seed=0):
    """A realistic blocked self-join catalog: contiguous same-size-ish
    blocks lowered through the production pair_range planner."""
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.choice(np.arange(1, n), n_blocks - 1, replace=False))
    sizes = np.diff(np.concatenate([[0], cuts, [n]]))
    bids = np.repeat(np.arange(n_blocks), sizes)
    bdm = compute_bdm(bids, np.zeros(n, np.int64), n_blocks, 1)
    return lower(plan_to_job(plan_pair_range(bdm, r)), bm, bn)


# ---------------------------------------------------------------------------
# Planner units
# ---------------------------------------------------------------------------

def test_default_group():
    assert default_group(16) == 4
    assert default_group(8) == 2
    assert default_group(7) == 1
    assert default_group(1) == 1
    assert default_group(64) == 8


def test_halo_hop_rows_and_bytes():
    assert halo_hop_rows(128, 300) == [128, 128, 44]
    assert halo_hop_rows(128, 128) == [128]
    assert halo_hop_rows(128, 5) == [5]
    assert halo_hop_rows(128, 0) == []
    # bytes received = exactly halo rows, regardless of hop count
    assert sum(halo_bytes_per_device(128, 300, 64)) == 300 * 64 * 4


def test_psum_bytes():
    # ring-reduce over the model axis: 2·(m−1)/m of the payload
    payload = 10 * 64 * 64 * 4
    assert psum_bytes_per_device(1, 10, 64, 64) == 0
    assert psum_bytes_per_device(2, 10, 64, 64) == payload
    assert psum_bytes_per_device(4, 10, 64, 64) == 2 * 3 * payload // 4


def test_ring_plan_locality_beats_flat():
    cat = _blocked_catalog()
    plan = plan_comms(cat, 1024, 8, policy="ring", feature_dim=64)
    assert plan.policy == "ring" and plan.fallback is None
    assert 0 < plan.hops < 7          # blocked locality < full exchange
    assert plan.device_of_tile.shape == (cat.num_tiles,)
    vol = plan.bytes_received_per_device()
    flat = plan_comms(cat, 1024, 8, policy="flat", feature_dim=64)
    assert vol["total"] < flat.bytes_received_per_device()["total"]


def test_hierarchical_plan_shape():
    cat = _blocked_catalog()
    plan = plan_comms(cat, 1024, 8, policy="hierarchical", feature_dim=64)
    assert plan.policy == "hierarchical" and plan.group == 2
    vol = plan.bytes_received_per_device()
    assert vol["hier_intra"] == (plan.group - 1) * plan.n_loc * 64 * 4
    # base is group-panel-aligned, one origin per device
    assert plan.base.shape == (8,)
    assert all(b % (plan.group * plan.n_loc) == 0 for b in plan.base)


def test_alignment_gates_degrade_to_flat():
    cat = _blocked_catalog()
    # rows not shard-divisible
    p = plan_comms(cat, 1001, 8, policy="ring", feature_dim=64)
    assert p.policy == "flat" and "divisible" in p.fallback
    # n_loc not a tile-geometry multiple (n_loc=96, bm=64)
    p = plan_comms(cat, 768, 8, policy="ring", feature_dim=64)
    assert p.policy == "flat" and p.fallback is not None


def test_pinned_hops():
    cat = _blocked_catalog()
    need = plan_comms(cat, 1024, 8, policy="ring", feature_dim=64).hops
    # pin below the need → degrade, never a recompile-shaped surprise
    p = plan_comms(cat, 1024, 8, policy="ring", feature_dim=64,
                   pin_hops=need - 1)
    assert p.policy == "flat" and "pinned" in p.fallback
    # pin above the need → over-gather at the pinned count (exact)
    p = plan_comms(cat, 1024, 8, policy="ring", feature_dim=64,
                   pin_hops=need + 2)
    assert p.policy == "ring" and p.hops == need + 2


def test_unknown_policy_raises():
    cat = _blocked_catalog()
    with pytest.raises(ValueError):
        plan_comms(cat, 1024, 8, policy="mesh2d", feature_dim=64)


def test_rewrite_tiles_local():
    tiles = np.zeros((2, 3, NCOLS), np.int32)
    tiles[0, 0, [A_TILE, R0, R1]] = [2, 128, 192]   # live, device 0
    tiles[1, 0, [A_TILE, R0, R1]] = [8, 512, 576]   # live, device 1
    base = np.array([128, 512])
    out = rewrite_tiles_local(tiles, base, 64, 64, shift_b=False)
    assert out[0, 0, A_TILE] == 0 and out[0, 0, R0] == 0
    assert out[1, 0, A_TILE] == 0 and out[1, 0, R0] == 0
    assert (out[0, 1] == 0).all()                   # dead tiles untouched
    with pytest.raises(ValueError):
        rewrite_tiles_local(tiles, np.array([100, 512]), 64, 64)


def test_comms_volume_scaling_64_dev():
    """The fig13 model: ring/hierarchical bytes-received per device drop
    from the all-gather's O(n) to O(n/n_dev · hops)."""
    cat = _blocked_catalog(n=4096, n_blocks=64)
    for n_dev in (16, 64):
        v = comms_volume(cat, 4096, n_dev, feature_dim=64)
        assert v["ring"] < v["flat_gather"]
        hier = v["hier_intra"] + v["hier_inter"]
        assert hier < v["flat_gather"]
        assert v["ring"] == v["ring_hops"] * (4096 // n_dev) * 64 * 4


def test_plan_summary_round_trips():
    cat = _blocked_catalog()
    plan = plan_comms(cat, 1024, 8, policy="ring", feature_dim=64)
    s = plan.summary()
    assert s["policy"] == "ring" and s["hops"] == plan.hops
    assert s["bytes_received_per_device"]["total"] > 0
    assert isinstance(plan, CommsPlan)


# ---------------------------------------------------------------------------
# Exact parity on simulated device grids (subprocess)
# ---------------------------------------------------------------------------

PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, numpy as np, jax.numpy as jnp
    from repro.sharding import make_er_mesh
    from repro.core import compute_bdm, plan_pair_range
    from repro.er.compiler import execute, lower, plan_comms, plan_to_job
    from repro.er.compiler.execute import stage1_stats

    N_DATA, N_MODEL = {n_data}, {n_model}
    BM = BN = 64
    n = N_DATA * 128                       # n_loc = 128, BM | n_loc
    d = 64
    rng = np.random.default_rng(7)
    cuts = np.sort(rng.choice(np.arange(1, n), 15, replace=False))
    bids = np.repeat(np.arange(16), np.diff(np.r_[0, cuts, n]))
    bdm = compute_bdm(bids, np.zeros(n, np.int64), 16, 1)
    cat = lower(plan_to_job(plan_pair_range(bdm, 8)), BM, BN)
    feats = rng.standard_normal((n, d)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)

    mesh = make_er_mesh(N_DATA, N_MODEL)
    model_axis = "model" if N_MODEL > 1 else None

    def survivors(comms, compact, use_mesh=True):
        ra, rb = execute(cat, jnp.asarray(feats), threshold=0.1,
                         impl="xla", mesh=mesh if use_mesh else None,
                         comms=comms, compact=compact,
                         model_axis=model_axis if use_mesh else None)
        return set(zip(ra.tolist(), rb.tolist()))

    ref = survivors("flat", True, use_mesh=False)   # single-host oracle
    assert ref, "degenerate test: no stage-1 survivors"
    for comms in ("flat", "ring", "hierarchical"):
        plan = plan_comms(cat, n, N_DATA, policy=comms, n_model=N_MODEL,
                          feature_dim=d, self_join=True)
        assert plan.fallback is None, (comms, plan.fallback)
        expect = plan.bytes_received_per_device()
        before = dict(stage1_stats["interconnect"])
        for compact in (True, False):
            got = survivors(comms, compact)
            assert got == ref, (comms, compact, len(got), len(ref))
        after = stage1_stats["interconnect"]
        # counters move exactly when the plan predicts traffic
        if comms == "ring":
            moved = after["ring_bytes"] > before["ring_bytes"]
            assert moved == (expect.get("ring", 0) > 0), (expect, after)
        if comms == "hierarchical":
            moved = (after["hier_intra_bytes"] + after["hier_inter_bytes"]
                     > before["hier_intra_bytes"] + before["hier_inter_bytes"])
            assert moved == (expect.get("total", 0) > 0), (expect, after)
        if N_MODEL > 1:
            assert after["psum_bytes"] > before["psum_bytes"]
    print("parity OK:", len(ref), "survivors on", N_DATA, "x", N_MODEL)
""")


@pytest.mark.slow
@pytest.mark.parametrize("n_data,n_model", [(2, 1), (4, 1), (8, 1),
                                            (8, 2), (16, 1)])
def test_comms_policy_parity(n_data, n_model):
    """Flat vs ring vs hierarchical — exact stage-1 survivor-set
    equality, mask AND compact epilogues, including a non-square (8, 2)
    data×model grid. The model-axis case uses a margin-safe threshold:
    the psum reassociates the d-dot, so only scores within ulps of the
    threshold itself could flip (see make_scorer's contract)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    script = PARITY.format(n_dev=n_data * n_model, n_data=n_data,
                           n_model=n_model)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "parity OK" in proc.stdout, proc.stdout + proc.stderr


MULTI_HOP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, numpy as np, jax.numpy as jnp
    from repro.er import make_products, sn_sort_order
    from repro.er.encode import encode_titles, ngram_features
    from repro.er.distributed import match_sn_dist, sn_replication_volume
    from repro.er.executor import verify_pairs
    from repro.sharding import make_er_mesh
    from sn_oracle import sn_oracle_matches

    n_dev, DIM, MAXLEN = 8, 128, 48
    ds = make_products(512, seed=9)
    n = ds.n - (ds.n % n_dev)
    titles = ds.titles[:n]
    W = n // n_dev + 37                    # w − 1 > n / n_dev: 2 hops

    order = sn_sort_order(titles)
    codes, lens = encode_titles(titles, MAXLEN)
    feats = ngram_features(codes, dim=DIM, lengths=lens)
    mesh = make_er_mesh(n_dev)
    ca, cb = match_sn_dist(jnp.asarray(feats[order]), W, mesh,
                           threshold=0.8 - 0.25)
    ha, hb = verify_pairs(codes[order], lens[order], codes[order],
                          lens[order], ca, cb, 0.8)
    got = set()
    for a, b in zip(ha, hb):
        ga, gb = int(order[a]), int(order[b])
        got.add((min(ga, gb), max(ga, gb)))
    want = sn_oracle_matches(titles, W, feature_dim=DIM, max_len=MAXLEN)
    assert got == want, (len(got), len(want))
    hops = -(-(W - 1) // (n // n_dev))
    assert hops >= 2, hops
    per_hop = sn_replication_volume(n, W, n_dev, DIM, per_hop=True)
    assert len(per_hop) == hops
    assert sum(per_hop) == (W - 1) * DIM * 4
    print("multi-hop oracle OK:", len(got), "matches,", hops, "hops")
""")


@pytest.mark.slow
def test_multi_hop_halo_vs_oracle():
    """RepSN at a window wider than a shard: the chained-hop halo
    exchange must reproduce the brute-force SN oracle exactly, and the
    per-hop byte schedule must sum to precisely (w − 1) rows."""
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = "src" + os.pathsep + here
    proc = subprocess.run([sys.executable, "-c", MULTI_HOP], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(here))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "multi-hop oracle OK" in proc.stdout, proc.stdout + proc.stderr
