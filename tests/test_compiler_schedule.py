"""Unified match-job compiler + cost-model scheduler (deterministic leg).

The invariants, shared with the hypothesis leg
(``test_schedule_properties.py`` imports the ``check_*`` functions and
fuzzes their inputs):

  * the exact tile cost model: per-tile live-pair counts equal the
    enumeration oracle and sum to the plan's total, for every strategy's
    geometry (windows, tri, corner cuts, the SN band);
  * scheduling is a pure permutation of ownership: any schedule (either
    policy, any device count) preserves catalog coverage and
    disjointness exactly, both through ``apply_schedule`` and through
    the per-device tile shards;
  * cost-LPT beats the reducer round-robin baseline on skewed BDMs
    (dominant-block Basic instances — the paper's skew-collapse case)
    and never loses more than one tile quantum on any instance;
  * exact match-set parity through the unified plan → job → catalog →
    schedule → execute path vs the reference executor for all five
    strategies (basic / block_split / pair_range / sorted_neighborhood /
    the two-source service), under both schedule policies.
"""
import numpy as np
import pytest

from repro.core import (plan_basic, plan_block_split, plan_pair_range,
                        plan_sorted_neighborhood)
from repro.core.two_source import TwoSourceBDM, plan_pair_range_2src
from repro.er import (ERConfig, ERService, ServiceConfig, cross_restrict,
                      make_products, run_er)
from repro.er.blocking import exponential_block_ids
from repro.er.compiler import (apply_schedule, cross_job,
                               enumerate_catalog_pairs, lower, plan_to_job,
                               schedule_tiles, tile_costs, tiles_for_devices)
from repro.er.compiler.ir import R1, RED, TileCatalog


# ---------------------------------------------------------------------------
# Shared check functions (fuzzed by test_schedule_properties.py)
# ---------------------------------------------------------------------------

def pair_multiset(catalog):
    ea, eb = enumerate_catalog_pairs(catalog)
    pairs = sorted(zip(ea.tolist(), eb.tolist()))
    assert len(pairs) == len(set(pairs)), "catalog covers some pair twice"
    return pairs


def _sub_catalog(cat, tiles):
    return TileCatalog(tiles=tiles, block_m=cat.block_m, block_n=cat.block_n,
                       n_rows_a=cat.n_rows_a, n_rows_b=cat.n_rows_b,
                       r=cat.r, total_pairs=0)


def check_tile_costs_exact(cat):
    """Closed-form per-tile live counts == the per-tile enumeration oracle,
    summing to the plan's exact pair count."""
    costs = tile_costs(cat)
    assert costs.shape[0] == cat.num_tiles
    per_tile = np.asarray(
        [len(pair_multiset(_sub_catalog(cat, cat.tiles[i:i + 1])))
         for i in range(cat.num_tiles)], np.int64)
    np.testing.assert_array_equal(costs, per_tile)
    assert int(costs.sum()) == cat.total_pairs


def check_schedule_preserves_coverage(cat, n_dev, policy):
    """A schedule moves ownership, never pairs: coverage/disjointness are
    preserved through apply_schedule AND through the device shards."""
    want = pair_multiset(cat)
    sched = schedule_tiles(cat, n_dev=n_dev, policy=policy)
    assert pair_multiset(apply_schedule(cat, sched)) == want
    assert (sched.tile_reducer >= 0).all()
    assert (sched.tile_reducer < cat.r).all()
    assert (0 <= sched.reducer_device).all()
    assert (sched.reducer_device < n_dev).all()
    assert int(sched.reducer_load.sum()) == cat.total_pairs
    assert int(sched.device_load.sum()) == cat.total_pairs

    tiles_dev = tiles_for_devices(cat, n_dev, schedule=sched)
    got = []
    for d in range(n_dev):
        shard = tiles_dev[d]
        live = shard[shard[:, R1] > 0]   # padding rows have empty windows
        got += pair_multiset(_sub_catalog(cat, live))
        # every live tile on device d is owned by a reducer placed on d
        assert (sched.reducer_device[live[:, RED]] == d).all()
    assert sorted(got) == want


def check_lpt_beats_round_robin(bdm, r, n_dev):
    """Basic hash-partitioning pins the dominant block's pairs to one
    reducer → one device; tile-level cost-LPT spreads them."""
    cat = lower(plan_to_job(plan_basic(bdm, r)), 32, 32)
    rr = schedule_tiles(cat, n_dev=n_dev, policy="round_robin")
    lpt = schedule_tiles(cat, n_dev=n_dev, policy="cost_lpt")
    assert int(lpt.device_load.max()) < int(rr.device_load.max())


def check_lpt_within_tile_quantum(cat, n_dev):
    """On ALREADY balanced plans (PairRange's ceil split) tile-level LPT
    cannot beat the exact pair split — but it never loses more than one
    tile of quantization."""
    rr = schedule_tiles(cat, n_dev=n_dev, policy="round_robin")
    lpt = schedule_tiles(cat, n_dev=n_dev, policy="cost_lpt")
    slack = int(lpt.tile_cost.max()) if lpt.tile_cost.size else 0
    assert int(lpt.device_load.max()) <= int(rr.device_load.max()) + slack


# ---------------------------------------------------------------------------
# Deterministic instance generators (the hypothesis leg draws its own)
# ---------------------------------------------------------------------------

def _rng_bdm(rng):
    b, m = int(rng.integers(1, 10)), int(rng.integers(1, 4))
    bdm = rng.integers(0, 12, (b, m)).astype(np.int64)
    if rng.random() < 0.5:
        bdm[int(rng.integers(0, b))] = int(rng.integers(20, 50))
    return bdm


def _catalog_zoo(rng):
    """One lowered catalog per strategy geometry, randomized instance."""
    r = int(rng.integers(1, 6))
    bm = int(rng.choice([16, 32]))
    bdm = _rng_bdm(rng)
    yield lower(plan_to_job(plan_basic(bdm, r)), bm, bm)
    yield lower(plan_to_job(plan_block_split(bdm, r)), bm, bm)
    yield lower(plan_to_job(plan_pair_range(bdm, r)), bm, bm)
    yield lower(plan_to_job(plan_sorted_neighborhood(
        int(rng.integers(2, 200)), int(rng.integers(2, 30)), r)), bm, bm)
    ra, rb_ = _rng_bdm(rng), _rng_bdm(rng)
    b = min(ra.shape[0], rb_.shape[0])
    bdm2 = TwoSourceBDM(bdm_r=ra[:b], bdm_s=rb_[:b])
    yield lower(plan_to_job(plan_pair_range_2src(bdm2, r)), bm, bm)
    yield lower(cross_job(int(rng.integers(1, 80)),
                          int(rng.integers(1, 40)), r), bm, bm)


def test_tile_costs_exact_all_strategies():
    rng = np.random.default_rng(7)
    for _ in range(6):
        for cat in _catalog_zoo(rng):
            check_tile_costs_exact(cat)


def test_schedule_preserves_coverage_all_strategies():
    rng = np.random.default_rng(11)
    for trial in range(4):
        for cat in _catalog_zoo(rng):
            check_schedule_preserves_coverage(
                cat, n_dev=int(rng.integers(1, 9)),
                policy=("cost_lpt", "round_robin")[trial % 2])


def test_cost_lpt_beats_round_robin_on_skew():
    rng = np.random.default_rng(13)
    for _ in range(10):
        b, m = int(rng.integers(3, 12)), int(rng.integers(1, 4))
        bdm = rng.integers(0, 6, (b, m)).astype(np.int64)
        big = int(rng.integers(128, 300))
        bdm[int(rng.integers(0, b))] = [big // m + (i < big % m)
                                        for i in range(m)]
        check_lpt_beats_round_robin(bdm, r=int(rng.integers(4, 16)),
                                    n_dev=int(rng.integers(2, 8)))


def test_cost_lpt_never_worse_than_a_tile_quantum():
    rng = np.random.default_rng(17)
    for _ in range(4):
        for cat in _catalog_zoo(rng):
            check_lpt_within_tile_quantum(cat, n_dev=int(rng.integers(2, 9)))


def test_schedule_respects_healthy_mask():
    rng = np.random.default_rng(19)
    for cat in _catalog_zoo(rng):
        healthy = np.array([False, True, True, False, True])
        sched = schedule_tiles(cat, n_dev=5, healthy=healthy,
                               policy="cost_lpt")
        dead = np.flatnonzero(~healthy)
        assert not np.isin(sched.reducer_device, dead).any()
        assert sched.device_load[dead].sum() == 0


# ---------------------------------------------------------------------------
# (c) match-set parity through the unified path, all five strategies
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def parity_ds():
    ds = make_products(700, seed=23)
    rng = np.random.default_rng(23)
    bid = exponential_block_ids(ds.n, b=20, s=1.0, rng=rng)
    return ds, bid


@pytest.mark.parametrize("strategy", ["basic", "block_split", "pair_range",
                                      "sorted_neighborhood"])
@pytest.mark.parametrize("policy", ["cost_lpt", "round_robin"])
def test_unified_path_parity(parity_ds, strategy, policy):
    """run_er through plan_to_job → lower → schedule → execute equals the
    reference per-reducer numpy executor — identical match sets under
    either schedule policy (scheduling moves work, never pairs)."""
    ds, bid = parity_ds
    base = dict(strategy=strategy, r=6, m=4, feature_dim=128, max_len=48,
                window=9)
    bids = None if strategy == "sorted_neighborhood" else bid
    ref = run_er(ds.titles, ERConfig(executor="reference", **base),
                 block_ids=bids)
    got = run_er(ds.titles, ERConfig(executor="catalog", kernel_impl="xla",
                                     schedule_policy=policy, **base),
                 block_ids=bids)
    assert got.matches == ref.matches
    assert got.total_pairs == ref.total_pairs
    np.testing.assert_array_equal(got.reducer_pairs, ref.reducer_pairs)
    assert got.schedule is not None and got.schedule["policy"] == policy
    assert got.schedule["total_cost"] == int(ref.reducer_pairs.sum())


@pytest.mark.parametrize("policy", ["cost_lpt", "round_robin"])
def test_unified_path_parity_two_source_service(parity_ds, policy):
    """The fifth strategy: the service's two-source query jobs through the
    same compiler equal the batch cross_restrict oracle."""
    ds, _ = parity_ds
    corpus = ds.titles[:240] + [""]
    queries = ds.titles[240:290] + ["", "@@@ fresh block"]
    cfg = ServiceConfig(feature_dim=128, max_len=48, r=8, m=4,
                        query_buckets=(16, 64), tile_chunk=32,
                        schedule_policy=policy)
    svc = ERService(corpus, cfg)
    got, off = set(), 0
    for sz in (17, 16, len(queries) - 33):
        for a, b in svc.match(queries[off:off + sz]):
            got.add((a, b + off))
        off += sz
    oracle = run_er(corpus + queries,
                    ERConfig(feature_dim=128, max_len=48, r=8, m=4))
    assert got == cross_restrict(oracle.matches, len(corpus))
