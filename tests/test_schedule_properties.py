"""Hypothesis leg of the compiler/scheduler invariants.

Fuzzes the SAME ``check_*`` functions as the deterministic leg
(``test_compiler_schedule.py``) over hypothesis-drawn strategy
instances: (a) coverage/disjointness is preserved under any schedule,
(b) cost-LPT makespan beats round-robin on dominant-block skew and
never loses more than a tile quantum elsewhere, plus the exactness of
the tile cost model everything rests on.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — skip, don't kill collection
from hypothesis import given, settings, strategies as st

from repro.core import (plan_basic, plan_block_split, plan_pair_range,
                        plan_sorted_neighborhood)
from repro.core.two_source import TwoSourceBDM, plan_pair_range_2src
from repro.er.compiler import cross_job, lower, plan_to_job

from test_compiler_schedule import (check_lpt_beats_round_robin,
                                    check_lpt_within_tile_quantum,
                                    check_schedule_preserves_coverage,
                                    check_tile_costs_exact)


@st.composite
def bdm_strategy(draw):
    """Small BDMs with empty blocks, singletons and a possible heavy hitter."""
    b = draw(st.integers(1, 10))
    m = draw(st.integers(1, 4))
    rows = [[draw(st.integers(0, 12)) for _ in range(m)] for _ in range(b)]
    if draw(st.booleans()):
        rows[draw(st.integers(0, b - 1))] = [draw(st.integers(20, 50))] * m
    return np.asarray(rows, np.int64)


@st.composite
def any_catalog(draw):
    """A lowered catalog from a random strategy over a random instance."""
    kind = draw(st.sampled_from(
        ["basic", "block_split", "pair_range", "sn", "2src", "cross"]))
    r = draw(st.integers(1, 6))
    bm = draw(st.sampled_from([16, 32]))
    if kind == "sn":
        plan = plan_sorted_neighborhood(draw(st.integers(2, 200)),
                                        draw(st.integers(2, 30)), r)
        return lower(plan_to_job(plan), bm, bm)
    if kind == "cross":
        return lower(cross_job(draw(st.integers(1, 80)),
                               draw(st.integers(1, 40)), r), bm, bm)
    if kind == "2src":
        ra, rb = draw(bdm_strategy()), draw(bdm_strategy())
        b = min(ra.shape[0], rb.shape[0])
        bdm2 = TwoSourceBDM(bdm_r=ra[:b], bdm_s=rb[:b])
        return lower(plan_to_job(plan_pair_range_2src(bdm2, r)), bm, bm)
    plan = {"basic": plan_basic, "block_split": plan_block_split,
            "pair_range": plan_pair_range}[kind](draw(bdm_strategy()), r)
    return lower(plan_to_job(plan), bm, bm)


@st.composite
def dominant_block_bdm(draw):
    """The paper's skew regime: one block ≫ everything else, spanning
    many catalog tiles (so tile-level LPT has room to spread it)."""
    b = draw(st.integers(3, 12))
    m = draw(st.integers(1, 4))
    rows = [[draw(st.integers(0, 6)) for _ in range(m)] for _ in range(b)]
    big = draw(st.integers(128, 300))
    rows[draw(st.integers(0, b - 1))] = [big // m + (i < big % m)
                                         for i in range(m)]
    return np.asarray(rows, np.int64)


@given(any_catalog())
@settings(max_examples=40, deadline=None)
def test_tile_costs_exact(cat):
    check_tile_costs_exact(cat)


@given(any_catalog(), st.integers(1, 8),
       st.sampled_from(["cost_lpt", "round_robin"]))
@settings(max_examples=40, deadline=None)
def test_schedule_preserves_coverage(cat, n_dev, policy):
    check_schedule_preserves_coverage(cat, n_dev, policy)


@given(dominant_block_bdm(), st.integers(4, 16), st.integers(2, 8))
@settings(max_examples=30, deadline=None)
def test_cost_lpt_beats_round_robin_on_skew(bdm, r, n_dev):
    check_lpt_beats_round_robin(bdm, r, n_dev)


@given(any_catalog(), st.integers(2, 8))
@settings(max_examples=30, deadline=None)
def test_cost_lpt_never_worse_than_a_tile_quantum(cat, n_dev):
    check_lpt_within_tile_quantum(cat, n_dev)


@given(any_catalog(), st.integers(1, 5), st.integers(0, 10 ** 6))
@settings(max_examples=40, deadline=None)
def test_calibrated_schedule_preserves_coverage(cat, n_dev, seed):
    """EWMA calibration re-weights placement only: any randomly-trained
    feedback model leaves pair coverage/disjointness and exact live-pair
    load accounting untouched."""
    from test_feedback_scheduling import \
        check_calibrated_schedule_preserves_coverage
    check_calibrated_schedule_preserves_coverage(cat, n_dev, seed)
