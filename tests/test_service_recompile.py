"""Recompile guard: after warmup, the resident service's steady state is
compile-free — 50 varied-size query batches (mixed keyed / null-key /
never-seen-block traffic) trigger ZERO new XLA compilations, counted via
``jax.monitoring`` backend-compile events, and land in the shape-bucket
histogram."""
import numpy as np

from repro.er import ERService, ServiceConfig, compile_counter, make_products

CFG = ServiceConfig(feature_dim=128, max_len=48, r=8, m=4,
                    query_buckets=(8, 32, 64), tile_chunk=64)


def test_compile_counter_sees_compiles():
    """The counter itself is live: a fresh jit shape registers > 0."""
    import jax, jax.numpy as jnp

    @jax.jit
    def f(x):
        return x * 2 + 1

    with compile_counter() as c:
        f(jnp.ones(3)).block_until_ready()
    assert c.count > 0
    with compile_counter() as c2:            # cache hit: silent
        f(jnp.ones(3)).block_until_ready()
    assert c2.count == 0


def test_zero_steady_state_recompiles():
    ds = make_products(450, seed=3)
    corpus = ds.titles[:400] + [""]          # null-key corpus row too
    svc = ERService(corpus, CFG)
    with compile_counter() as warm:
        svc.warmup()
    assert warm.count > 0                    # warmup is where compiles go
    # synthetic warmup batches stay out of the served-traffic profile
    assert int(svc.traffic_bdm.sum()) == 0
    assert svc.stats["batches"] == 0

    rng = np.random.default_rng(1)
    pool = ds.titles[400:] + ["", "@@@ new block title 01"]
    with compile_counter() as steady:
        for _ in range(50):
            sz = int(rng.integers(1, 65))    # spans all three buckets
            svc.match([pool[int(rng.integers(0, len(pool)))]
                       for _ in range(sz)])
    assert steady.count == 0, (
        f"{steady.count} XLA compilations in steady state — the shape "
        "buckets / fixed tile chunks are leaking shapes")
    assert svc.stats["batches"] == 50
    # varied sizes really did spread over the compiled-shape buckets
    hits = svc.stats["bucket_hits"]
    assert sum(hits.values()) == 50
    assert sum(1 for v in hits.values() if v > 0) >= 2


def test_warmup_then_single_compiled_set_per_bucket():
    """Serving the same bucket twice reuses the first batch's shapes:
    batch 2 compiles nothing even without a full warmup."""
    ds = make_products(300, seed=6)
    svc = ERService(ds.titles[:250], CFG)
    svc.match(ds.titles[250:258])            # bucket 8, compiles
    with compile_counter() as c:
        svc.match(ds.titles[258:264])        # bucket 8 again (size 6)
    assert c.count == 0
