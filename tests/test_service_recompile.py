"""Recompile guard: after warmup, the resident service's steady state is
compile-free — 50 varied-size query batches (mixed keyed / null-key /
never-seen-block traffic) trigger ZERO new XLA compilations, counted via
``jax.monitoring`` backend-compile events, and land in the shape-bucket
histogram."""
import numpy as np

from repro.er import ERService, ServiceConfig, compile_counter, make_products

CFG = ServiceConfig(feature_dim=128, max_len=48, r=8, m=4,
                    query_buckets=(8, 32, 64), tile_chunk=64)


def test_compile_counter_sees_compiles():
    """The counter itself is live: a fresh jit shape registers > 0."""
    import jax, jax.numpy as jnp

    @jax.jit
    def f(x):
        return x * 2 + 1

    with compile_counter() as c:
        f(jnp.ones(3)).block_until_ready()
    assert c.count > 0
    with compile_counter() as c2:            # cache hit: silent
        f(jnp.ones(3)).block_until_ready()
    assert c2.count == 0


def test_compile_counter_is_reentrant():
    """Nesting the SAME instance keeps one counting window: the count
    resets only on the outermost __enter__, and the inner __exit__ does
    not tear the window down."""
    import jax, jax.numpy as jnp

    c = compile_counter()
    with c:
        @jax.jit
        def f(x):
            return x * 3

        f(jnp.ones(7)).block_until_ready()
        seen = c.count
        assert seen > 0
        with c:                              # nested enter: no reset
            assert c.count == seen
        assert c.count == seen               # inner exit: still counting

        @jax.jit
        def g(x):
            return x * 5

        g(jnp.ones(9)).block_until_ready()
        assert c.count > seen
    final = c.count
    # outside every counter, compilations are no longer attributed
    @jax.jit
    def h(x):
        return x * 7

    h(jnp.ones(11)).block_until_ready()
    assert c.count == final


def test_compile_counter_concurrent_threads():
    """Concurrent counters don't race on listener (un)registration, and
    each open counter observes at least its own thread's compilation
    (counters are global by design — cross-thread compiles count too)."""
    import threading

    import jax, jax.numpy as jnp

    n = 4
    barrier = threading.Barrier(n)
    errors = []

    def worker(k):
        try:
            @jax.jit
            def f(x):                        # fresh identity + shape per
                return x + k                 # thread → guaranteed compile

            barrier.wait()
            with compile_counter() as c:
                f(jnp.ones(3 + k)).block_until_ready()
            assert c.count >= 1, f"thread {k} saw no compilations"
        except Exception as e:               # surface into the main thread
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors


def test_zero_steady_state_recompiles():
    ds = make_products(450, seed=3)
    corpus = ds.titles[:400] + [""]          # null-key corpus row too
    svc = ERService(corpus, CFG)
    with compile_counter() as warm:
        svc.warmup()
    assert warm.count > 0                    # warmup is where compiles go
    # synthetic warmup batches stay out of the served-traffic profile
    assert int(svc.traffic_bdm.sum()) == 0
    assert svc.stats["batches"] == 0

    rng = np.random.default_rng(1)
    pool = ds.titles[400:] + ["", "@@@ new block title 01"]
    with compile_counter() as steady:
        for _ in range(50):
            sz = int(rng.integers(1, 65))    # spans all three buckets
            svc.match([pool[int(rng.integers(0, len(pool)))]
                       for _ in range(sz)])
    assert steady.count == 0, (
        f"{steady.count} XLA compilations in steady state — the shape "
        "buckets / fixed tile chunks are leaking shapes")
    assert svc.stats["batches"] == 50
    # varied sizes really did spread over the compiled-shape buckets
    hits = svc.stats["bucket_hits"]
    assert sum(hits.values()) == 50
    assert sum(1 for v in hits.values() if v > 0) >= 2


def test_warmup_then_single_compiled_set_per_bucket():
    """Serving the same bucket twice reuses the first batch's shapes:
    batch 2 compiles nothing even without a full warmup."""
    ds = make_products(300, seed=6)
    svc = ERService(ds.titles[:250], CFG)
    svc.match(ds.titles[250:258])            # bucket 8, compiles
    with compile_counter() as c:
        svc.match(ds.titles[258:264])        # bucket 8 again (size 6)
    assert c.count == 0
