"""Runtime-feedback scheduling: the EWMA cost model, calibrated
schedules, mid-stream work stealing — and the three supervisor timing
bugfixes that shipped with them.

Contracts under test (DESIGN.md §Scheduling feedback loop):

  * the EWMA model learns per-device seconds-per-live-pair from shard
    records and falls back (device, class) → device → global;
  * calibration re-weights *placement only* — a calibrated schedule
    preserves exact pair coverage/disjointness, and supervised execution
    with feedback + stealing returns exactly the quiet match set;
  * under a seeded sticky straggler the stolen-tile makespan beats the
    static schedule by a wide margin;
  * regressions: backoff sleeps are clamped to the remaining request
    deadline, chaos latency is split real-vs-injected on the records,
    and ``ServiceUnavailable.retry_after_s`` tracks the live breaker
    cooldown instead of a constant.

The hypothesis leg of the calibrated-schedule invariant lives with the
other schedule properties (``test_schedule_properties.py``); the
deterministic seed sweep here runs without the optional dep.
"""
import time

import numpy as np
import pytest

from repro.er import (ERService, ServiceConfig, ServiceUnavailable,
                      make_products)
from repro.er.compiler import (EwmaCostModel, N_TILE_CLASSES, FaultEvent,
                               FaultInjector, FaultScript, apply_schedule,
                               cross_job, execute, execute_supervised, lower,
                               plan_to_job, schedule_tiles, tile_class)
from repro.core import (plan_basic, plan_pair_range,
                        plan_sorted_neighborhood, compute_bdm)

from test_compiler_schedule import pair_multiset

BM = BN = 32
THRESH = 0.4


def _feats(n: int, seed: int, dim: int = 32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(n, dim)).astype(np.float32)
    return f / np.linalg.norm(f, axis=1, keepdims=True)


def _catalog(strategy: str, sizes, r: int):
    sizes = np.asarray(sizes, np.int64)
    n = int(sizes.sum())
    if strategy == "sorted_neighborhood":
        plan = plan_sorted_neighborhood(n, w=5, r=r)
    else:
        bdm = compute_bdm(np.repeat(np.arange(sizes.size), sizes),
                          np.zeros(n, np.int64), sizes.size, 1)
        plan = {"basic": plan_basic,
                "pair_range": plan_pair_range}[strategy](bdm, r)
    return lower(plan_to_job(plan), BM, BN), n


def _pairs(ra, rb):
    return set(zip(ra.tolist(), rb.tolist()))


def _quiet(catalog, feats, feats_b=None):
    return _pairs(*execute(catalog, feats, feats_b, threshold=THRESH))


# ---------------------------------------------------------------------------
# EWMA cost model
# ---------------------------------------------------------------------------

def test_tile_class_partitions_by_predicate_shape():
    sn, _ = _catalog("sorted_neighborhood", [40] * 3, r=4)
    assert (tile_class(sn) == 2).any()            # SN band tiles
    basic, _ = _catalog("basic", [80, 30], r=4)
    assert (tile_class(basic) == 1).any()         # self-join triangles
    cross = lower(cross_job(70, 20, 4), BM, BN)
    assert (tile_class(cross) == 0).all()         # plain rectangles
    for cat in (sn, basic, cross):
        cls = tile_class(cat)
        assert cls.shape == (cat.num_tiles,)
        assert ((cls >= 0) & (cls < N_TILE_CLASSES)).all()


def test_ewma_learns_device_rates_and_predicts():
    fb = EwmaCostModel(3, alpha=0.5)
    even = np.zeros(N_TILE_CLASSES)
    even[0] = 1000.0
    for _ in range(6):
        fb.observe(0, even, seconds=1e-3)         # 1e-6 s/pair: fast
        fb.observe(1, even, seconds=5e-3)         # 5e-6 s/pair: slow
    rates = fb.device_rates()
    assert rates[0] == pytest.approx(1e-6, rel=1e-6)
    assert rates[1] == pytest.approx(5e-6, rel=1e-6)
    # unseen device 2 falls back to the global blend, between the two
    assert rates[0] < rates[2] < rates[1]
    # prediction scales linearly in cost and respects device speed
    assert fb.predict(1, even) == pytest.approx(5 * fb.predict(0, even))
    assert fb.predict(0, 2 * even) == pytest.approx(2 * fb.predict(0, even))


def test_ewma_resolution_fallback_class_then_device_then_global():
    fb = EwmaCostModel(2)
    only_band = np.zeros(N_TILE_CLASSES)
    only_band[2] = 500.0
    fb.observe(0, only_band, seconds=1e-3)
    assert fb.rate(0, 2) == pytest.approx(2e-6)   # observed (dev, class)
    assert fb.rate(0, 1) == pytest.approx(2e-6)   # class unseen → device
    assert fb.rate(1) == fb.global_rate           # device unseen → global
    assert fb.observations == 1


def test_ewma_rejects_bad_inputs():
    with pytest.raises(ValueError):
        EwmaCostModel(0)
    with pytest.raises(ValueError):
        EwmaCostModel(2, alpha=0.0)
    fb = EwmaCostModel(2)
    with pytest.raises(ValueError):
        fb.observe(0, np.zeros(N_TILE_CLASSES + 1), 1.0)
    fb.observe(0, np.zeros(N_TILE_CLASSES), 1.0)  # zero cost: no-op
    assert fb.observations == 0


# ---------------------------------------------------------------------------
# Calibrated schedules preserve the compiler's invariants
# ---------------------------------------------------------------------------

def _trained_model(n_dev: int, seed: int) -> EwmaCostModel:
    rng = np.random.default_rng(seed)
    fb = EwmaCostModel(n_dev)
    for _ in range(int(rng.integers(1, 12))):
        cost = rng.integers(0, 2000, N_TILE_CLASSES).astype(np.float64)
        fb.observe(int(rng.integers(0, n_dev)), cost,
                   seconds=float(rng.uniform(1e-4, 1e-1)))
    return fb


def check_calibrated_schedule_preserves_coverage(cat, n_dev, seed):
    """Calibration re-weights placement, never pairs: coverage and
    disjointness survive, loads stay exact live-pair counts. Shared by
    the deterministic sweep here and the hypothesis leg in
    ``test_schedule_properties.py``."""
    fb = _trained_model(n_dev, seed)
    sched = schedule_tiles(cat, n_dev=n_dev, feedback=fb)
    assert pair_multiset(apply_schedule(cat, sched)) == pair_multiset(cat)
    assert int(sched.reducer_load.sum()) == cat.total_pairs
    assert int(sched.device_load.sum()) == cat.total_pairs
    if fb.observations and cat.num_tiles:
        assert sched.calibrated
        stats = sched.stats()
        assert stats["calibrated"]
        assert stats["predicted_makespan_s"] >= 0.0


def test_calibrated_schedule_preserves_coverage_sweep():
    rng = np.random.default_rng(42)
    for seed in range(12):
        strategy = ["basic", "pair_range", "sorted_neighborhood"][seed % 3]
        sizes = rng.integers(1, 60, size=int(rng.integers(1, 6)))
        cat, _ = _catalog(strategy, sizes, r=int(rng.integers(1, 7)))
        check_calibrated_schedule_preserves_coverage(
            cat, n_dev=int(rng.integers(1, 6)), seed=seed)
    cross = lower(cross_job(77, 23, 5), BM, BN)
    check_calibrated_schedule_preserves_coverage(cross, n_dev=3, seed=99)


def test_calibrated_supervised_matches_uncalibrated_exactly():
    cat, n = _catalog("pair_range", [90, 40, 12, 3], r=8)
    f = _feats(n, 7)
    want = _quiet(cat, f)
    fb = _trained_model(4, seed=11)
    ra, rb, rep = execute_supervised(cat, f, threshold=THRESH, n_dev=4,
                                     feedback=fb, steal_factor=2.0,
                                     steal_quantum=2)
    assert _pairs(ra, rb) == want
    assert rep.coverage == 1.0
    assert rep.predicted_makespan_s > 0.0       # trained model: calibrated
    assert rep.measured_makespan_s > 0.0


# ---------------------------------------------------------------------------
# The straggler drill: stealing beats the static schedule
# ---------------------------------------------------------------------------

def test_steal_beats_static_under_sticky_straggler():
    cat, n = _catalog("pair_range", [120, 60, 30, 14, 6], r=16)
    f = _feats(n, 5)
    want = _quiet(cat, f)
    script = FaultScript(events=(
        FaultEvent("straggle", 1, 0, delay=0.25, sticky=True),), n_dev=4)

    def run(steal_factor):
        ra, rb, rep = execute_supervised(
            cat, f, threshold=THRESH, n_dev=4, max_retries=2, backoff=0.0,
            injector=FaultInjector(script), steal_quantum=4,
            steal_factor=steal_factor,
            feedback=EwmaCostModel(4) if steal_factor else None)
        assert _pairs(ra, rb) == want           # exact quiet match set
        assert rep.coverage == 1.0
        return rep

    static = run(None)
    stolen = run(2.0)
    assert static.steals == 0 and static.stolen_tiles == 0
    assert stolen.steals >= 1 and stolen.stolen_tiles > 0
    # same dispatch quantum on both sides: the win is pure re-placement
    assert static.measured_makespan_s >= 1.5 * stolen.measured_makespan_s


def test_sticky_straggle_cleared_by_revive():
    inj = FaultInjector(FaultScript(events=(
        FaultEvent("straggle", 0, 0, delay=3.0, sticky=True),
        FaultEvent("revive", 0, 3)), n_dev=2))
    assert inj.shard_call(0).delay == 3.0       # step 1: slow
    assert inj.shard_call(0).delay == 3.0       # step 2: still slow
    assert inj.slow_devices == {0: 3.0}
    assert inj.shard_call(0).delay == 0.0       # step 3: revived
    assert inj.slow_devices == {}


# ---------------------------------------------------------------------------
# Regression: backoff sleeps never overshoot the request deadline
# ---------------------------------------------------------------------------

def test_backoff_sleep_clamped_to_remaining_deadline():
    cat, n = _catalog("pair_range", [70, 30], r=4)
    f = _feats(n, 4)
    script = FaultScript(events=tuple(
        FaultEvent("transient", 0, s) for s in range(0, 12)), n_dev=2)
    slept = []
    deadline = 30.0
    execute_supervised(cat, f, threshold=THRESH, n_dev=2, max_retries=3,
                       backoff=100.0, deadline=deadline, sleep=slept.append,
                       partial=True, injector=FaultInjector(script))
    assert slept                                # retries did back off …
    assert all(s <= deadline for s in slept)    # … but never past the
    assert max(slept) < 100.0                   #     deadline (was 100s+)


def test_zero_deadline_sleeps_zero_and_degrades():
    cat, n = _catalog("pair_range", [70, 30], r=4)
    f = _feats(n, 4)
    slept = []
    ra, rb, rep = execute_supervised(
        cat, f, threshold=THRESH, n_dev=2, backoff=50.0, deadline=0.0,
        sleep=slept.append, partial=True)
    assert slept == [] and ra.size == 0 and rep.coverage == 0.0


# ---------------------------------------------------------------------------
# Regression: records split real wall time from injected virtual delay
# ---------------------------------------------------------------------------

def test_latency_stats_exclude_virtual_delay():
    cat, n = _catalog("pair_range", [80, 25], r=4)
    f = _feats(n, 6)
    want = _quiet(cat, f)
    big = 1e6
    inj = FaultInjector(FaultScript(events=(
        FaultEvent("straggle", 0, 0, delay=big),), n_dev=2))
    ra, rb, rep = execute_supervised(cat, f, threshold=THRESH, n_dev=2,
                                     injector=inj)   # no shard deadline
    assert _pairs(ra, rb) == want
    hit = [r for r in rep.records if r.injected_delay == big]
    assert len(hit) == 1 and hit[0].status == "ok"
    assert hit[0].elapsed < 50.0                # real seconds, not 1e6+
    assert hit[0].busy == pytest.approx(hit[0].elapsed + big)
    # the virtual clock DOES see the delay — it models the slow fleet
    assert rep.measured_makespan_s >= big


def test_virtual_delay_still_drives_shard_timeout():
    cat, n = _catalog("pair_range", [80, 25], r=4)
    f = _feats(n, 6)
    inj = FaultInjector(FaultScript(events=(
        FaultEvent("straggle", 0, 0, delay=1e6),), n_dev=2))
    ra, rb, rep = execute_supervised(cat, f, threshold=THRESH, n_dev=2,
                                     shard_deadline=100.0, backoff=0.0,
                                     injector=inj)
    assert _pairs(ra, rb) == _quiet(cat, f)     # recovered elsewhere
    assert any(r.status == "timeout" for r in rep.records)


# ---------------------------------------------------------------------------
# Regression: retry_after_s tracks the live breaker cooldown
# ---------------------------------------------------------------------------

DS = make_products(250, seed=3)
CORPUS = DS.titles[:140]
QUERIES = DS.titles[140:170]


def _svc_cfg(**kw):
    base = dict(feature_dim=128, max_len=48, r=8, m=4,
                query_buckets=(8, 32), tile_chunk=64)
    base.update(kw)
    return ServiceConfig(**base)


def test_breaker_readmission_resets_stale_ewma():
    """REGRESSION (PR 8): a breaker-readmitted device kept the EWMA
    rates it accumulated WHILE it straggled, so feedback scheduling kept
    starving a now-healthy device indefinitely (EWMA decay from a 1e6×
    outlier takes hundreds of folds). Readmission resets the device's
    rates to the global fallback — one probe restores its placement
    share."""
    svc = ERService(CORPUS, _svc_cfg(exec_devices=2,
                                     feedback_scheduling=True,
                                     breaker_cooldown_s=0.05))
    fb = svc.feedback
    even = np.zeros(N_TILE_CLASSES)
    even[0] = 1000.0
    for _ in range(6):
        fb.observe(1, even, seconds=1e2)      # straggle era: 0.1 s/pair
    for _ in range(40):
        fb.observe(0, even, seconds=1e-4)     # healthy fleet: 1e-7 s/pair
    stale = fb.rate(1)
    cat, _ = _catalog("pair_range", [90, 40, 12], r=8)
    starved = schedule_tiles(cat, n_dev=2, feedback=fb)
    assert starved.device_load[1] / cat.total_pairs < 0.05
    svc._breaker_open[1] = time.monotonic() - 1.0   # cooldown elapsed
    svc._probe_evicted()                      # probe succeeds → readmit
    assert not svc._breaker_open
    assert svc.stats["breaker_readmissions"] == 1
    assert fb.rate(1) < stale / 50            # stale rates forgotten
    recovered = schedule_tiles(cat, n_dev=2, feedback=fb)
    assert recovered.device_load[1] / cat.total_pairs > 0.3


def test_readmitted_device_recovers_placement_share():
    """End to end: device 1 dies and is evicted with terrible
    straggle-era EWMA rates on the books; after a revive the probe
    readmits it, the reset drops the stale rates, and the very next
    batches place real work on it again (its rate is re-learned from
    accepted shard calls instead of staying pinned at the outlier)."""
    svc = ERService(CORPUS, _svc_cfg(exec_devices=2,
                                     feedback_scheduling=True,
                                     backoff_s=0.0, breaker_threshold=1,
                                     breaker_cooldown_s=0.0))
    svc.warmup()
    want = set(ERService(CORPUS, _svc_cfg()).match(QUERIES[:8]))
    fb = svc.feedback
    svc.set_fault_injector(FaultInjector(FaultScript(events=(
        FaultEvent("kill", 1, 0), FaultEvent("revive", 1, 12)), n_dev=2)))
    assert set(svc.match(QUERIES[:8])) == want      # recovered on dev 0
    assert svc.stats["breaker_evictions"] >= 1
    # the rates device 1 accrued while it declined: 1000 s per live pair
    # (absurdly slow — EWMA decay alone would need dozens of folds, and
    # feedback placement would never give it the calls to fold)
    fb._dev[1] = 1e3
    fb._cls[1, :] = 1e3
    for _ in range(12):                       # serve until a probe lands
        assert set(svc.match(QUERIES[:8])) == want
        if svc.stats["breaker_readmissions"]:
            break
    assert svc.stats["breaker_readmissions"] >= 1
    for _ in range(3):                        # healthy traffic re-learns
        assert set(svc.match(QUERIES[:8])) == want
    assert not np.isnan(fb._dev[1])           # it DID get work again
    # re-learned from real shard calls, not decayed off the outlier —
    # without the reset this stays >= 1e3 * 0.65^folds >> 1
    assert fb.rate(1) < 1.0


def test_retry_after_tracks_remaining_cooldown():
    cooldown = 5.0
    svc = ERService(CORPUS, ServiceConfig(
        feature_dim=128, max_len=48, r=8, m=4, query_buckets=(8, 32),
        tile_chunk=64, exec_devices=2, backoff_s=0.0, breaker_threshold=1,
        breaker_cooldown_s=cooldown))
    svc.set_fault_injector(FaultInjector(FaultScript(events=(
        FaultEvent("kill", 0, 0), FaultEvent("kill", 1, 0)), n_dev=2)))
    resp = svc.match(QUERIES[:6])
    assert resp.degraded                        # both devices evicted
    with pytest.raises(ServiceUnavailable) as e1:
        svc.match(QUERIES[:6])
    assert 0.0 < e1.value.retry_after_s <= cooldown   # was a fixed 1.0
    time.sleep(0.2)
    with pytest.raises(ServiceUnavailable) as e2:
        svc.match(QUERIES[:6])
    # the advertised wait shrinks as the cooldown actually elapses
    assert e2.value.retry_after_s <= e1.value.retry_after_s - 0.15
