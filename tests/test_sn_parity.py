"""Sorted Neighborhood parity: the SN strategy through the tile-catalog
executor (interpret-mode Pallas and the XLA twin) and the reference
per-reducer numpy path must all produce the IDENTICAL match set as the
O(n·w) windowed-pair brute-force oracle on a seeded skewed dataset —
mirroring test_executor_parity.py — plus the band-catalog coverage
invariants and the acceptance balance bar (max/mean ≤ 1.2 at r=32)."""
import numpy as np
import pytest
from sn_oracle import sn_band_pairs_bruteforce, sn_oracle_matches

from repro.core import plan_sorted_neighborhood
from repro.core.sorted_neighborhood import pairs_of_band_range
from repro.er import ERConfig, make_products, run_er
from repro.er.blocking import exponential_block_ids
from repro.er.executor import build_catalog, enumerate_catalog_pairs

WINDOW = 12
BASE = dict(strategy="sorted_neighborhood", window=WINDOW, r=8,
            feature_dim=128, max_len=48)


@pytest.fixture(scope="module")
def skewed_ds():
    # Same seeded skewed corpus as test_executor_parity (the Fig. 9
    # skew=1.0 block ids exist for the balance test; SN itself slides a
    # window over the sort order, independent of any block distribution).
    ds = make_products(1200, seed=11)
    rng = np.random.default_rng(11)
    bid = exponential_block_ids(ds.n, b=30, s=1.0, rng=rng)
    return ds, bid


@pytest.fixture(scope="module")
def oracle(skewed_ds):
    ds, _ = skewed_ds
    return sn_oracle_matches(ds.titles, WINDOW, feature_dim=128, max_len=48)


@pytest.mark.parametrize("kernel_impl", ["interpret", "xla"])
def test_sn_catalog_matches_oracle(skewed_ds, oracle, kernel_impl):
    """Acceptance bar: exact oracle match set for both kernel impls."""
    ds, _ = skewed_ds
    res = run_er(ds.titles, ERConfig(executor="catalog",
                                     kernel_impl=kernel_impl, **BASE))
    assert res.matches == oracle
    assert res.total_pairs == res.reducer_pairs.sum()


def test_sn_reference_matches_oracle(skewed_ds, oracle):
    ds, _ = skewed_ds
    res = run_er(ds.titles, ERConfig(executor="reference", **BASE))
    assert res.matches == oracle


def test_sn_end_to_end_executor_leg(skewed_ds, oracle, executor):
    """The CI matrix leg: whole SN pipeline under --executor=<leg>."""
    ds, _ = skewed_ds
    res = run_er(ds.titles, ERConfig(executor=executor, **BASE))
    assert res.matches == oracle
    assert res.map_output_size > 0


def test_sn_balance_on_fig9_skew(skewed_ds):
    """Acceptance bar: reducer-load imbalance (max/mean planned pairs)
    ≤ 1.2 at r=32 — the band partition is skew-free by construction, so
    the Fig. 9 s=1.0 block distribution cannot unbalance it."""
    ds, bid = skewed_ds
    cfg = ERConfig(strategy="sorted_neighborhood", window=WINDOW, r=32,
                   feature_dim=128, max_len=48)
    res = run_er(ds.titles, cfg, block_ids=bid)   # block_ids ignored by SN
    loads = res.reducer_pairs
    assert loads.sum() == res.total_pairs
    assert loads.max() / loads.mean() <= 1.2


def test_sn_window_covers_full_triangle_at_w_ge_n():
    """w ≥ n degenerates to the all-pairs triangle."""
    n = 40
    plan = plan_sorted_neighborhood(n, n + 5, 4)
    assert plan.total_pairs == n * (n - 1) // 2
    seen = set()
    for k in range(plan.r):
        ra, rb = pairs_of_band_range(plan, k)
        seen.update(zip(ra.tolist(), rb.tolist()))
    assert seen == sn_band_pairs_bruteforce(n, n + 5)


@pytest.mark.parametrize("bm,bn", [(32, 32), (32, 64)])
@pytest.mark.parametrize("n,w,r", [(300, 17, 7), (130, 64, 3), (50, 2, 5)])
def test_sn_catalog_covers_band_exactly(n, w, r, bm, bn):
    """Every band pair appears in the band-diagonal catalog exactly once,
    nothing else does — for unaligned strips and off-diagonal windows."""
    plan = plan_sorted_neighborhood(n, w, r)
    cat = build_catalog(plan, block_m=bm, block_n=bn)
    ea, eb = enumerate_catalog_pairs(cat)
    got = set(zip(ea.tolist(), eb.tolist()))
    assert len(got) == ea.size, "catalog covers some band pair twice"
    assert got == sn_band_pairs_bruteforce(n, w)
    assert cat.total_pairs == len(got)


def test_sn_catalog_tiles_hug_the_band():
    """The tile count scales with the band, not the n×n triangle: a thin
    window over many rows must not emit O((n/bm)^2) tiles."""
    plan = plan_sorted_neighborhood(4096, 10, 8)
    cat = build_catalog(plan, block_m=128, block_n=128)
    n_strips = 4096 // 128
    assert cat.num_tiles <= 3 * n_strips        # ~2 per strip row for w≪bm
    assert cat.num_tiles < n_strips * n_strips / 4
