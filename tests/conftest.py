"""Shared test configuration.

``--executor`` selects the ``ERConfig.executor`` used by the end-to-end
tests that honor the ``executor`` fixture — CI runs the tier-1 suite once
per leg (catalog | reference) so both execution paths stay green.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--executor", action="store", default="catalog",
        choices=("catalog", "reference"),
        help="ERConfig.executor for executor-parameterized tests")


@pytest.fixture
def executor(request) -> str:
    return request.config.getoption("--executor")
