"""Training step factory: loss, grads, AdamW update — one jit-able pure
function per (model, optimizer) pair."""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from ..models import get_model
from ..models.config import ModelConfig
from ..sharding import constrain
from .optimizer import AdamWConfig, adamw_update, cosine_lr

__all__ = ["loss_fn", "make_train_step"]


def loss_fn(params, batch, cfg: ModelConfig, aux_weight: float = 0.01):
    """Next-token cross entropy (labels = batch['labels'], −100 ignored)
    + MoE router auxiliary loss where applicable."""
    mod = get_model(cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        logits, aux = mod.forward(params, batch, cfg, return_aux=True)
    else:
        logits = mod.forward(params, batch, cfg)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, logz - gold, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    xent = nll.sum() / denom
    return xent + aux_weight * aux, {"xent": xent, "aux": aux}


def make_train_step(cfg: ModelConfig, opt: AdamWConfig = AdamWConfig(),
                    total_steps: int = 10_000) -> Callable:
    """Returns step(params, opt_state, batch) → (params, opt_state, metrics).

    Data parallelism comes from sharded inputs (pjit); no explicit pmean —
    XLA inserts the gradient all-reduce from the sharding constraints.
    """

    def step(params, opt_state, batch):
        batch = {k: constrain(v, ("pod", "data")) for k, v in batch.items()}
        (loss, stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        lr = cosine_lr(opt_state["step"], peak=opt.lr, total=total_steps)
        params, opt_state, opt_stats = adamw_update(
            grads, opt_state, params, opt, lr=lr)
        metrics = {"loss": loss, "lr": lr, **stats, **opt_stats}
        return params, opt_state, metrics

    return step
