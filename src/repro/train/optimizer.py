"""AdamW, hand-rolled (no optax offline): global-norm clipping, bias
correction, decoupled weight decay, cosine LR schedule.

``moment_dtype="bfloat16"`` halves optimizer memory — at qwen3-moe-235B
scale that is the difference between fitting and OOMing a 16 GiB v5e
chip under FSDP×EP sharding (see EXPERIMENTS.md §Dry-run). Moments are
kept in bf16 storage but updated in f32 arithmetic.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "cosine_lr"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_lr(step, *, peak: float, warmup: int = 100, total: int = 10_000,
              floor_frac: float = 0.1):
    warm = peak * (step + 1) / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(grads, opt_state, params, cfg: AdamWConfig = AdamWConfig(),
                 lr=None):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
