"""Step-sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json       — tree structure, shapes, dtypes, step
           shard_<i>.npz       — flat leaves, round-robin over shards

Design points for the 1000-node story (DESIGN.md §3):
  * every leaf is saved *unsharded* (gathered) — restore therefore works
    under ANY device count / mesh shape: elasticity comes from re-jitting
    with the new mesh's shardings, not from matching shard files;
  * shard files are written round-robin so hosts write in parallel
    (here: one process writes all shards);
  * writes are atomic (tmp dir + rename) so a killed run never leaves a
    half checkpoint — restart safety;
  * an ``async_save`` double-buffers the host copy and writes on a
    background thread, overlapping I/O with the next step (the BDM-style
    "plan is recomputable, data is tiny" argument does the rest for the
    ER jobs).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "async_save"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any, step: int, num_shards: int = 4) -> str:
    leaves, treedef = _flatten(tree)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    shards: Dict[int, Dict[str, np.ndarray]] = {i: {} for i in range(num_shards)}
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        shards[i % num_shards][f"leaf_{i}"] = arr
        meta.append({"index": i, "shard": i % num_shards,
                     "shape": list(arr.shape), "dtype": str(arr.dtype)})
    for s, data in shards.items():
        np.savez(os.path.join(tmp, f"shard_{s}.npz"), **data)
    manifest = {"step": step, "num_shards": num_shards, "leaves": meta,
                "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, step: Optional[int] = None) -> Tuple[Any, int]:
    """Returns (tree of np arrays, step). Re-shard by feeding the tree to
    a jit with the target in_shardings (device_put happens there)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    from jax.tree_util import PyTreeDef, default_registry
    treedef = PyTreeDef.deserialize_using_proto(
        default_registry, bytes.fromhex(manifest["treedef"]))
    shard_data = {}
    for s in range(manifest["num_shards"]):
        with np.load(os.path.join(d, f"shard_{s}.npz")) as z:
            shard_data.update({k: z[k] for k in z.files})
    leaves = [shard_data[f"leaf_{m['index']}"] for m in manifest["leaves"]]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class async_save:
    """Background-thread checkpoint writer with a single in-flight slot
    (double buffering: the host copy happens on the caller's thread — the
    device buffers are free immediately; the disk write overlaps the next
    training step)."""

    def __init__(self, path: str, num_shards: int = 4):
        self.path = path
        self.num_shards = num_shards
        self._thread: Optional[threading.Thread] = None

    def __call__(self, tree: Any, step: int):
        host_tree = jax.tree.map(np.asarray, tree)   # sync host copy
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.path, host_tree, step, self.num_shards),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
