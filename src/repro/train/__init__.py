from .optimizer import adamw_init, adamw_update, global_norm  # noqa: F401
from .train_step import loss_fn, make_train_step  # noqa: F401
