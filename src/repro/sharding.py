"""Sharding rules: param/activation PartitionSpecs over the production
mesh axes ("pod", "data", "model").

Strategy (DESIGN.md §3):
  * 2-D weight sharding — tensor-parallel dims over ``model``, FSDP over
    ("pod", "data") on the other large dim.
  * MoE experts — expert-parallel over ``model`` (experts/16 per group),
    FSDP over ("pod", "data") on d_model.
  * Activations — batch over ("pod", "data"); layer-boundary constraints
    only, GSPMD propagates inside the layer.
  * KV caches — batch over ("pod", "data"), kv-heads over ``model``
    (when divisible; GQA with few kv heads falls back to replicated
    heads — re-sharding the sequence axis instead is a §Perf hillclimb).

Rules are name-pattern based over the param pytree paths, so every model
family gets specs without per-model tables. ``constrain`` is a no-op
outside a mesh context, keeping single-device smoke tests mesh-free.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["constrain", "param_spec", "param_shardings", "batch_spec",
           "cache_spec", "cache_shardings", "make_er_mesh",
           "DP_AXES", "TP_AXIS"]

DP_AXES = ("pod", "data")
TP_AXIS = "model"


def make_er_mesh(n_data: int, n_model: int = 1) -> Mesh:
    """The ER executor's 2-D ``(data, model)`` mesh: corpus rows shard
    over ``data``, the hashed-n-gram feature dimension over ``model``
    (``compiler.execute(model_axis="model")`` psums the partial tile
    scores). Reuses the train substrate's axis names so the same mesh
    can carry both workloads; ``n_model=1`` is the classic 1-D data
    mesh every existing ER path runs on. Devices reshape row-major —
    the ``model`` axis varies fastest, keeping a model group's devices
    adjacent (the higher-bandwidth hop, same discipline as the dp×mp
    train meshes)."""
    devices = np.asarray(jax.devices())
    if devices.size < n_data * n_model:
        raise ValueError(f"need {n_data * n_model} devices for a "
                         f"({n_data}, {n_model}) mesh, "
                         f"have {devices.size}")
    grid = devices[:n_data * n_model].reshape(n_data, n_model)
    try:
        return Mesh(grid, ("data", TP_AXIS),
                    axis_types=(jax.sharding.AxisType.Auto,) * 2)
    except (AttributeError, TypeError):   # older jax: no AxisType/kwarg
        return Mesh(grid, ("data", TP_AXIS))


def _active_axes() -> Tuple[str, ...]:
    m = jax.sharding.get_abstract_mesh()
    return tuple(m.axis_names) if m is not None and not m.empty else ()


def _filter_spec(spec: Tuple, axes: Tuple[str, ...]) -> P:
    """Drop mesh axes that do not exist in the active mesh (lets the same
    rules serve the (data, model) single-pod and (pod, data, model)
    multi-pod meshes and the 1-device test mesh)."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in axes)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in axes else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context."""
    axes = _active_axes()
    if not axes:
        return x
    return jax.lax.with_sharding_constraint(x, _filter_spec(spec, axes))


def act_constrain(x, mode: str):
    """Layer-boundary activation constraint for a (B, S, d) tensor.

    "full_dp" puts the batch over EVERY mesh axis (pure ZeRO-style data
    parallelism) — the right layout for recurrent trunks (rwkv6/zamba2),
    whose time scans otherwise force per-layer sequence all-gathers."""
    if mode == "seq":
        return constrain(x, DP_AXES, TP_AXIS, None)
    if mode == "d":
        return constrain(x, DP_AXES, None, TP_AXIS)
    if mode == "full_dp":
        return constrain(x, DP_AXES + (TP_AXIS,), None, None)
    return constrain(x, DP_AXES, None, None)


def attn_logits_constrain(x):
    """Shard (B, G, KV, Q, S) attention logits over the model axis.

    Preference order: group dim (g-major head layout makes this the
    common case, e.g. qwen3-moe's 64h/4kv → g=16), kv dim (MHA), else
    the key/sequence dim (split-K — softmax partials psum'd by GSPMD).
    Without this, GQA head counts not divisible by tp leave the logits
    replicated — tens of GiB per chunk at 32k context."""
    axes = _active_axes()
    if not axes or TP_AXIS not in axes:
        return x
    tp = jax.sharding.get_abstract_mesh().shape[TP_AXIS]
    if tp <= 1:
        return x
    _, g, kv, _, s = x.shape
    if g % tp == 0:
        return constrain(x, DP_AXES, TP_AXIS, None, None, None)
    if kv % tp == 0:
        return constrain(x, DP_AXES, None, TP_AXIS, None, None)
    if s % tp == 0:
        return constrain(x, DP_AXES, None, None, None, TP_AXIS)
    return constrain(x, DP_AXES, None, None, None, None)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (path-regex, spec for the *last* ndim dims). Layer stacking adds a
# leading None automatically. First match wins.
_RULES = [
    # --- MoE experts: (E, d, f) — EP over model, FSDP on d ---
    (r"experts.*(w_gate|w_up)", (TP_AXIS, DP_AXES, None)),
    (r"experts.*w_down", (TP_AXIS, None, DP_AXES)),
    (r"router", (DP_AXES, None)),
    # --- embeddings / lm head ---
    # embed: vocab over dp, d over model — a vocab-over-model lookup makes
    # GSPMD all-gather the full table per device (measured: ×26 f32 copies
    # of a 2.4 GiB table on qwen3-moe)
    (r"embed", (DP_AXES, TP_AXIS)),
    (r"(^|/)head", (DP_AXES, TP_AXIS)),
    (r"vis_proj", (DP_AXES, TP_AXIS)),
    # --- attention ---
    (r"wq|wk|wv|w_qkv", (DP_AXES, TP_AXIS)),
    (r"wo", (TP_AXIS, DP_AXES)),
    (r"b[qkv]$", (TP_AXIS,)),
    # --- dense FFN ---
    (r"w_gate|w_up|w_in|fc1", (DP_AXES, TP_AXIS)),
    (r"w_down|w_out|fc2", (TP_AXIS, DP_AXES)),
    # --- rwkv6 time-mix / channel-mix ---
    (r"(w_r|w_k|w_v|w_g)$", (DP_AXES, TP_AXIS)),
    (r"w_wkv_out", (TP_AXIS, DP_AXES)),
    (r"w_decay$", (DP_AXES, TP_AXIS)),
    (r"w_decay_b", (TP_AXIS,)),
    (r"cm_(k|r)", (DP_AXES, TP_AXIS)),
    (r"cm_v", (TP_AXIS, DP_AXES)),
    # --- mamba2 ---
    (r"in_proj", (DP_AXES, TP_AXIS)),
    (r"out_proj", (TP_AXIS, DP_AXES)),
    (r"conv_w", (TP_AXIS, None)),
    (r"(A_log|D$|dt_bias|conv_b)", (TP_AXIS,)),
]


def param_spec(path: str, ndim: int, stacked: int) -> P:
    """PartitionSpec for one param leaf given its flattened path.
    ``stacked`` = number of leading layer-stack dims (zamba2's blocks
    carry two: (n_super, every, ...))."""
    eff = ndim - stacked
    lead = (None,) * stacked
    for pat, spec in _RULES:
        if re.search(pat, path):
            if len(spec) == eff:
                return lead + tuple(spec)
            # bias-like reduced rank: keep the last axes of the rule
            if eff == 1 and len(spec) >= 1:
                return lead + (spec[-1],)
    return ((None,) * ndim)  # norms, scalars: replicated


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_shardings(params_shape: Any, mesh: Mesh):
    """Pytree of NamedShardings matching ``params_shape`` (a pytree of
    arrays or ShapeDtypeStructs)."""
    axes = tuple(mesh.axis_names)

    def one(path, leaf):
        ps = _path_str(path)
        stacked = 2 if "blocks" in ps else (1 if "layers" in ps else 0)
        spec = param_spec(ps, len(leaf.shape), stacked)
        # Never shard a dim that isn't divisible by the axis size.
        sized = []
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                sized.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            names = tuple(a for a in names if a in axes)
            size = int(np.prod([mesh.shape[a] for a in names])) if names else 1
            if names and dim % size == 0:
                sized.append(names if len(names) > 1 else names[0])
            else:
                sized.append(None)
        return NamedSharding(mesh, _filter_spec(tuple(sized), axes))

    return jax.tree_util.tree_map_with_path(one, params_shape)


_CACHE_RULES = [
    # (pattern, spec-for-trailing-dims after the leading layer-stack dim)
    (r"^(k|v|xk|xv)$", ("B", "S", TP_AXIS, None)),       # (L, B, S, KV, hd)
    (r"^wkv$", ("B", TP_AXIS, None, None)),              # (L, B, H, hd, hd)
    (r"^(tm_x|cm_x)$", ("B", TP_AXIS)),                  # (L, B, d)
    (r"^ssm$", (None, "B", TP_AXIS, None, None)),        # (nsup, every, B, nh, hd, ds)
    (r"^conv$", (None, "B", None, TP_AXIS)),             # (nsup, every, B, K-1, C)
]


def cache_shardings(cache_shape: Any, mesh: Mesh, batch_size: int):
    """NamedShardings for a serve cache pytree.

    'B' entries shard batch over (pod, data) when divisible; for the
    k/v caches, when the batch cannot shard (long_500k batch=1) the
    *sequence* dim takes the dp axes instead — context parallelism."""
    axes = tuple(mesh.axis_names)
    dp = int(np.prod([mesh.shape[a] for a in DP_AXES if a in axes]))
    tp = mesh.shape.get(TP_AXIS, 1) if TP_AXIS in axes else 1
    batch_ok = dp > 1 and batch_size % dp == 0

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        for pat, rule in _CACHE_RULES:
            if not re.search(pat, name):
                continue
            spec = [None]  # leading layer-stack dim
            dims = leaf.shape[1:]
            if rule[0] == "B" and rule[1] == "S":   # k/v caches
                s_dim, h_dim = dims[1], dims[2]
                heads_ok = tp > 1 and h_dim % tp == 0
                # batch → dp; kv-heads → model when divisible, else the
                # sequence takes the model axis (flash-decoding split-K /
                # context parallelism) so a 32k cache never sits whole on
                # one chip; long_500k (batch 1) puts dp on the sequence.
                seq_axes = []
                if batch_ok:
                    spec.append(DP_AXES)
                else:
                    spec.append(None)
                    if dp > 1:
                        seq_axes += [a for a in DP_AXES if a in axes]
                if not heads_ok and TP_AXIS in axes and tp > 1:
                    seq_axes.append(TP_AXIS)
                sz = int(np.prod([mesh.shape[a] for a in seq_axes])) if seq_axes else 1
                if seq_axes and s_dim % sz == 0:
                    spec.append(tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0])
                else:
                    spec.append(None)
                spec.append(TP_AXIS if heads_ok else None)
                spec.append(None)
                return NamedSharding(mesh, _filter_spec(tuple(spec), axes))
            for dim, entry in zip(dims, rule):
                if entry == "B":
                    spec.append(DP_AXES if batch_ok else None)
                elif entry == "S":
                    spec.append(None)
                elif entry == TP_AXIS:
                    spec.append(TP_AXIS if (tp > 1 and dim % tp == 0) else None)
                else:
                    spec.append(None)
            return NamedSharding(mesh, _filter_spec(tuple(spec), axes))
        return NamedSharding(mesh, P())  # pos scalar etc.

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_spec(mesh: Mesh) -> P:
    """Token batches: batch dim over (pod, data)."""
    return _filter_spec((DP_AXES,), tuple(mesh.axis_names))


def cache_spec(mesh: Mesh, n_kv_heads: int, batch_size: int) -> P:
    """KV cache (L, B, S, KV, hd): B over (pod, data) when divisible,
    kv-heads over model when divisible; else sequence over model."""
    axes = tuple(mesh.axis_names)
    dp = int(np.prod([mesh.shape[a] for a in DP_AXES if a in axes]))
    tp = mesh.shape.get(TP_AXIS, 1) if TP_AXIS in axes else 1
    b_entry = DP_AXES if batch_size % max(dp, 1) == 0 and dp > 1 else None
    if n_kv_heads % max(tp, 1) == 0 and tp > 1:
        spec = (None, b_entry, None, TP_AXIS, None)
    else:
        spec = (None, b_entry, TP_AXIS, None, None)  # context-parallel seq
    return _filter_spec(spec, axes)
