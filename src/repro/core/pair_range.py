"""PairRange (paper §V, Alg. 2).

All P pairs get a global index via the closed-form enumeration
(core/enumeration.py); the index space is cut into r near-equal ranges and
range k *is* reduce task k. Map sends an entity to every range that contains
at least one of its pairs (the exact union, not just the [Rmin, Rmax] span).

TPU mapping: a device owning range [lo, hi) materializes its pair list with
the vectorized inverse ``p -> (block, x, y)`` and gathers the two feature
rows per pair from the blocked layout. The per-(device, block) *gather set*
is provably a union of <= 2 contiguous row intervals (see
:func:`range_block_intervals`), which is what the collective-volume
accounting (Fig. 12 analog: bytes over ICI) and the sharded executor use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from . import enumeration as en

__all__ = [
    "PairRangePlan",
    "plan_pair_range",
    "pairs_of_range",
    "pairs_of_range_jnp",
    "range_block_segments",
    "range_block_intervals",
    "entity_range_matrix",
    "map_output_size",
]


@dataclass(frozen=True)
class PairRangePlan:
    r: int
    bdm: np.ndarray            # (b, m)
    block_sizes: np.ndarray    # (b,)
    pair_counts: np.ndarray    # (b,)
    offsets: np.ndarray        # (b,) o(i), exclusive cumsum of pair_counts
    estart: np.ndarray         # (b,) entity-row offset per block (blocked layout)
    bounds: np.ndarray         # (r, 2) [lo, hi) pair-index bounds
    total_pairs: int

    @property
    def reducer_pairs(self) -> np.ndarray:
        return (self.bounds[:, 1] - self.bounds[:, 0]).astype(np.int64)


def plan_pair_range(bdm: np.ndarray, r: int) -> PairRangePlan:
    bdm = np.asarray(bdm, np.int64)
    sizes = bdm.sum(axis=1)
    pairs = en.block_pair_counts(sizes)
    offsets, total = en.pair_offsets(pairs)
    estart = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)[:-1]])
    bounds = en.range_bounds(total, r)
    return PairRangePlan(
        r=r, bdm=bdm, block_sizes=sizes, pair_counts=pairs,
        offsets=offsets, estart=estart, bounds=bounds, total_pairs=total)


def pairs_of_range(plan: PairRangePlan, k: int):
    """Materialize range k's pairs: (block, x, y, row_a, row_b) int64 arrays."""
    lo, hi = plan.bounds[k]
    p = np.arange(lo, hi, dtype=np.int64)
    block, x, y = en.invert_pair_index(p, plan.block_sizes, plan.offsets)
    return block, x, y, plan.estart[block] + x, plan.estart[block] + y


def pairs_of_range_jnp(sizes, offsets, estart, lo, count: int, total: int):
    """jnp twin with a static pair count (padded past ``total``).

    Returns (row_a, row_b, valid) — padded entries get row 0 and valid=False.
    All inputs are jnp arrays / traced scalars except the static ``count``.
    """
    import jax.numpy as jnp

    idx_dtype = sizes.dtype
    p = lo + jnp.arange(count, dtype=idx_dtype)
    valid = p < total
    pc = jnp.where(valid, p, 0)
    block = jnp.searchsorted(offsets, pc, side="right") - 1
    q = pc - offsets[block]
    n = sizes[block]
    # Float estimate of the triangular root, then integer boundary repair.
    af = (2 * n - 1).astype(jnp.float32)
    disc = jnp.maximum(af * af - 8.0 * q.astype(jnp.float32), 0.0)
    est = (af - jnp.sqrt(disc)) / 2.0
    x = jnp.clip(jnp.floor(est).astype(q.dtype), 0, jnp.maximum(n - 2, 0))
    # 8 repair passes cover float32 estimate error of up to +/-8; the
    # property tests sweep N to verify exactness for the supported sizes.
    for _ in range(8):
        s_x = (x * (2 * n - x - 1)) // 2
        x = jnp.where(s_x > q, x - 1, x)
        s_x1 = ((x + 1) * (2 * n - x - 2)) // 2
        x = jnp.where(s_x1 <= q, x + 1, x)
    x = jnp.clip(x, 0, jnp.maximum(n - 2, 0))
    y = q - (x * (2 * n - x - 1)) // 2 + x + 1
    return estart[block] + x, estart[block] + y, valid


def range_block_segments(plan: PairRangePlan, k: int) -> List[Tuple[int, int, int, int, int]]:
    """Per-block pair segments of range k: [(block, x_lo, y_lo, x_hi, y_hi)].

    Range k's pair-index interval [lo, hi) intersected with block ``blk``
    is a contiguous run of cell indices, i.e. (in the column-major
    triangular enumeration) the cells from (x_lo, y_lo) through
    (x_hi, y_hi) inclusive: a prefix-cut first column, full middle
    columns, a suffix-cut last column. This is the O(1)-per-block
    description the tile-catalog executor compiles to corner-cut masks —
    no per-pair materialization. Only blocks with a non-empty segment are
    returned; coordinates are block-local.
    """
    lo, hi = map(int, plan.bounds[k])
    if hi <= lo:
        return []
    sizes, offsets = plan.block_sizes, plan.offsets
    b_lo, _, _ = en.invert_pair_index(np.int64(lo), sizes, offsets)
    b_hi, _, _ = en.invert_pair_index(np.int64(hi - 1), sizes, offsets)
    out = []
    for blk in range(int(b_lo), int(b_hi) + 1):
        n = int(sizes[blk])
        npairs = int(plan.pair_counts[blk])
        if npairs == 0:
            continue
        qlo = max(lo - int(offsets[blk]), 0)
        qhi = min(hi - int(offsets[blk]), npairs) - 1
        if qhi < qlo:
            continue
        x_lo, y_lo = (int(v) for v in en.invert_cell_index(np.int64(qlo), n))
        x_hi, y_hi = (int(v) for v in en.invert_cell_index(np.int64(qhi), n))
        out.append((blk, x_lo, y_lo, x_hi, y_hi))
    return out


def range_block_intervals(plan: PairRangePlan, k: int) -> List[Tuple[int, List[Tuple[int, int]]]]:
    """Per-block gather intervals (<= 2 each) for range k.

    Returns [(block, [(row_lo, row_hi_inclusive), ...]), ...] in blocked-
    layout rows. Proof sketch of the <=2 bound: within one block a
    contiguous pair-index interval covers columns x_lo..x_hi; if it spans
    >= 3 columns, some middle column is complete, whose y-values reach
    N-1, collapsing the union to a single interval [x_lo, N-1]; otherwise
    the union is [x_lo, ...] plus at most one y-tail.
    """
    sizes, estart = plan.block_sizes, plan.estart
    out = []
    for blk, x_lo, y_lo, x_hi, y_hi in range_block_segments(plan, k):
        n = int(sizes[blk])
        if x_hi >= x_lo + 2:
            ivs = [(x_lo, n - 1)]
        elif x_hi == x_lo:
            if y_lo == x_lo + 1:
                ivs = [(x_lo, y_hi)]
            else:
                ivs = [(x_lo, x_lo), (y_lo, y_hi)]
        else:  # x_hi == x_lo + 1
            first = (x_lo, y_hi)          # [x_lo, x_lo+1] ∪ [x_hi+1, y_hi]
            second = (y_lo, n - 1)        # y-tail of the partial first column
            if second[0] <= first[1] + 1:
                ivs = [(x_lo, n - 1)]
            else:
                ivs = [first, second]
        base = int(estart[blk])
        out.append((blk, [(base + a, base + b) for a, b in ivs]))
    return out


def entity_range_matrix(plan: PairRangePlan, max_pairs: int = 50_000_000) -> np.ndarray:
    """Exact (n_entities, r) bool membership — which ranges each entity is
    sent to (the union Alg. 2 computes map-side). Brute-force over all
    pairs, chunked; intended for DS1-scale benchmarks/tests."""
    if plan.total_pairs > max_pairs:
        raise ValueError(f"{plan.total_pairs} pairs exceeds brute-force budget")
    n = int(plan.block_sizes.sum())
    mask = np.zeros((n, plan.r), bool)
    per = -(-plan.total_pairs // plan.r) if plan.total_pairs else 1
    chunk = 4_000_000
    for lo in range(0, plan.total_pairs, chunk):
        p = np.arange(lo, min(lo + chunk, plan.total_pairs), dtype=np.int64)
        blk, x, y = en.invert_pair_index(p, plan.block_sizes, plan.offsets)
        rng = np.minimum(p // per, plan.r - 1)
        mask[plan.estart[blk] + x, rng] = True
        mask[plan.estart[blk] + y, rng] = True
    return mask


def map_output_size(plan: PairRangePlan) -> int:
    """kv-pairs emitted by map (Fig. 12): sum over entities of the number
    of relevant ranges, equivalently sum over ranges of the gather-set
    size. Closed form via the <=2-interval bound of
    :func:`range_block_intervals` — O(r + b) work, never O(P), so it is
    exact at any scale (DS2's 6.7·10⁹ pairs included).
    ``entity_range_matrix`` remains the brute-force oracle in tests."""
    total = 0
    for k in range(plan.r):
        for _, ivs in range_block_intervals(plan, k):
            total += sum(hi - lo + 1 for lo, hi in ivs)
    return total
