"""Task -> reduce-task assignment (shared by BlockSplit and the MoE layer).

The paper's BlockSplit assigns match tasks with a greedy LPT heuristic:
sort tasks by pair count descending, then repeatedly give the next task to
the reduce task with the fewest assigned pairs (§IV, Alg. 1 lines 22-27).

Two twins:
  * :func:`greedy_lpt` — numpy host planning (dynamic task count).
  * :func:`greedy_lpt_jnp` — jnp/jit-able (static shapes) via lax.scan with
    a running-load argmin; reused by models/moe.py balanced dispatch where
    the "tasks" are experts and the loads are token counts.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["greedy_lpt", "greedy_lpt_hetero", "greedy_lpt_jnp",
           "makespan_stats"]


def greedy_lpt(weights: np.ndarray, r: int) -> Tuple[np.ndarray, np.ndarray]:
    """Assign each weighted task to one of ``r`` bins, largest-first.

    Returns ``(assignment, loads)`` — assignment[t] in [0, r), loads (r,).
    Ties broken by lowest bin index (paper's getNextReduceTask).
    """
    w = np.asarray(weights, np.int64)
    order = np.argsort(-w, kind="stable")
    assignment = np.empty(w.shape[0], np.int64)
    loads = np.zeros(r, np.int64)
    for t in order:
        k = int(np.argmin(loads))
        assignment[t] = k
        loads[k] += w[t]
    return assignment, loads


def greedy_lpt_hetero(weights, rates) -> Tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """LPT over *heterogeneous* bins: assign each task (largest first) to
    the bin that would finish it earliest, ``(load_k + w) * rates[k]``.

    ``rates`` are per-bin seconds-per-unit-work (a slow device has a
    larger rate); with equal rates this degenerates to :func:`greedy_lpt`
    up to ties. Returns ``(assignment, loads, finish)`` — loads in work
    units, finish in seconds. Used by the runtime-feedback scheduler to
    place reducer loads onto EWMA-measured devices.
    """
    w = np.asarray(weights, np.float64)
    rates = np.maximum(np.asarray(rates, np.float64), 1e-300)
    order = np.argsort(-w, kind="stable")
    assignment = np.empty(w.shape[0], np.int64)
    loads = np.zeros(rates.shape[0], np.float64)
    for t in order:
        k = int(np.argmin((loads + w[t]) * rates))
        assignment[t] = k
        loads[k] += w[t]
    return assignment, loads, loads * rates


def greedy_lpt_jnp(weights, r: int):
    """jnp twin of :func:`greedy_lpt` (jit-able; O(T·r) scan)."""
    import jax
    import jax.numpy as jnp

    w = weights
    order = jnp.argsort(-w, stable=True)

    def step(loads, t):
        k = jnp.argmin(loads)
        loads = loads.at[k].add(w[t])
        return loads, k

    loads, bins_sorted = jax.lax.scan(step, jnp.zeros(r, w.dtype), order)
    assignment = jnp.zeros_like(order).at[order].set(bins_sorted)
    return assignment, loads


def makespan_stats(loads: np.ndarray) -> dict:
    """Balance metrics used across benchmarks (paper's implicit metric)."""
    loads = np.asarray(loads, np.float64)
    total = loads.sum()
    mean = total / loads.shape[0] if loads.shape[0] else 0.0
    mx = loads.max() if loads.size else 0.0
    return {
        "total": float(total),
        "mean": float(mean),
        "max": float(mx),
        "imbalance": float(mx / mean) if mean > 0 else 1.0,
        "idle_frac": float(1.0 - total / (mx * loads.shape[0])) if mx > 0 else 0.0,
    }
