"""Pair enumeration for PairRange (paper §V, Appendix I).

The paper enumerates, per block ``Φ_i`` of size ``N``, all unordered pairs
``(x, y)`` with ``x < y`` in *column-major* order:

    c(x, y, N) = x/2 * (2N - x - 3) + y - 1            (one source)
    c(x, y, N) = x * N + y                             (two sources, |Φ_S|=N)

and offsets the per-block index by the number of pairs in preceding blocks:

    o(i) = 1/2 * sum_{k<i} |Φ_k| (|Φ_k| - 1)           (one source)
    o(i) = sum_{k<i} |Φ_k,R| * |Φ_k,S|                 (two sources)

(The paper's Appendix I prints ``o(i) = Σ... - 1``; with that constant the
very first pair would get index -1, contradicting Fig. 15(b). We drop the
spurious ``-1`` — a typo in the paper.)

This module provides the forward maps exactly as in the paper plus the
**closed-form inverses** ``p -> (block, x, y)`` that the TPU execution path
needs: a device owning pair range ``[lo, hi)`` materializes its pair list
with a vectorized inverse instead of Hadoop's group-iterator.

All functions are pure and work on either numpy or jax.numpy arrays (host
planning uses numpy int64; in-jit code uses jnp). ``xp`` is inferred from
the inputs where it matters.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "cell_index",
    "cell_index_2src",
    "column_start",
    "column_of_cell",
    "invert_cell_index",
    "invert_cell_index_2src",
    "block_pair_counts",
    "block_pair_counts_2src",
    "pair_offsets",
    "pair_index",
    "invert_pair_index",
    "range_of_pair",
    "range_bounds",
]


# ---------------------------------------------------------------------------
# Per-block cell enumeration (paper eq. (1))
# ---------------------------------------------------------------------------

def cell_index(x, y, n):
    """Paper's ``c(x, y, N)``: index of pair (x, y), x < y, in a block of
    size ``n`` under column-major upper-triangular enumeration."""
    return (x * (2 * n - x - 3)) // 2 + y - 1


def cell_index_2src(x, y, n_s):
    """Two-source ``c(x, y, N) = x*N + y`` (x indexes R, y indexes S)."""
    return x * n_s + y


def column_start(x, n):
    """Number of pairs in columns ``0..x-1`` = index of the first pair of
    column ``x``, i.e. ``c(x, x+1, n)``.  S(x) = x(2n - x - 1)/2."""
    return (x * (2 * n - x - 1)) // 2


def column_of_cell(q, n):
    """Inverse of :func:`column_start`: the column ``x`` containing local
    cell index ``q`` (0 <= q < n(n-1)/2).

    Closed form via the triangular root, with a two-step Newton/boundary
    correction so it is exact for every representable integer input (the
    float estimate can be off by one near column boundaries).
    Works elementwise on arrays.
    """
    # Estimate from solving S(x) <= q:  x = floor(((2n-1) - sqrt((2n-1)^2 - 8q)) / 2)
    a = 2 * n - 1
    disc = a * a - 8 * q
    # Guard: q may equal the last valid index; disc >= 1 there.
    est = (a - np.sqrt(np.maximum(disc, 0).astype(np.float64))) / 2.0
    x = np.floor(est).astype(getattr(q, "dtype", np.int64))
    x = np.clip(x, 0, np.maximum(n - 2, 0))
    # Boundary corrections (two passes cover float error of +/-1 each way).
    for _ in range(2):
        x = np.where(column_start(x, n) > q, x - 1, x)
        x = np.where(column_start(x + 1, n) <= q, x + 1, x)
    return np.clip(x, 0, np.maximum(n - 2, 0))


def invert_cell_index(q, n):
    """Inverse of :func:`cell_index`: local cell ``q`` -> (x, y)."""
    x = column_of_cell(q, n)
    y = q - column_start(x, n) + x + 1
    return x, y


def invert_cell_index_2src(q, n_s):
    """Inverse of :func:`cell_index_2src`: ``q -> (x, y)``."""
    return q // n_s, q % n_s


# ---------------------------------------------------------------------------
# Cross-block offsets (paper's o(i)) and global pair indexing
# ---------------------------------------------------------------------------

def block_pair_counts(sizes):
    """Pairs per block: |Φ|(|Φ|-1)/2. ``sizes`` int array (b,)."""
    s = sizes.astype(np.int64) if hasattr(sizes, "astype") else np.asarray(sizes, np.int64)
    return (s * (s - 1)) // 2


def block_pair_counts_2src(sizes_r, sizes_s):
    """Pairs per block for two sources: |Φ_R| * |Φ_S|."""
    r = np.asarray(sizes_r, np.int64)
    s = np.asarray(sizes_s, np.int64)
    return r * s


def pair_offsets(pair_counts):
    """o(i) for every block, plus total P: exclusive cumsum.

    Returns ``(offsets, total)`` with ``offsets.shape == pair_counts.shape``.
    """
    counts = np.asarray(pair_counts, np.int64)
    csum = np.cumsum(counts)
    total = int(csum[-1]) if counts.size else 0
    offsets = np.concatenate([np.zeros(1, np.int64), csum[:-1]])
    return offsets, total


def pair_index(block, x, y, sizes, offsets):
    """Global pair index p_i(x, y) (paper eq. (1)), vectorized."""
    n = sizes[block]
    return offsets[block] + cell_index(x, y, n)


def invert_pair_index(p, sizes, offsets):
    """Global pair index -> (block, x, y). Vectorized over ``p``.

    ``offsets`` must be the exclusive-cumsum from :func:`pair_offsets` and
    ``sizes`` the per-block entity counts. Blocks with zero pairs occupy an
    empty interval and are never returned.
    """
    p = np.asarray(p)
    # block = rightmost i with offsets[i] <= p  (searchsorted on the right).
    block = np.searchsorted(offsets, p, side="right") - 1
    # Skip backwards over empty blocks (offsets repeat for 0-pair blocks):
    # searchsorted('right') already lands on the *last* block with that
    # offset only if it has pairs covering p; for ties, the last tied block
    # is correct because preceding tied blocks contribute zero pairs.
    q = p - offsets[block]
    x, y = invert_cell_index(q, sizes[block])
    return block, x, y


# ---------------------------------------------------------------------------
# Pair ranges (paper eq. (2) / Alg. 2's ceil scheme)
# ---------------------------------------------------------------------------

def range_of_pair(p, total, r):
    """Range (= reduce task) index of pair ``p``.

    We use Alg. 2's scheme: ``k = floor(p / ceil(P/r))`` — the first r-1
    ranges hold ``ceil(P/r)`` pairs, the last the remainder. (Eq. (2)'s
    ``floor(r*p/P)`` differs only in boundary placement; both are "almost
    equal" splits. Alg. 2 is what the paper implements.)
    """
    per = -(-total // r) if total else 1  # ceil(P/r), guard P=0
    return np.minimum(np.asarray(p) // per, r - 1)


def range_bounds(total, r):
    """``[lo, hi)`` pair-index bounds per range, shape (r, 2)."""
    per = -(-total // r) if total else 0
    lo = np.minimum(np.arange(r, dtype=np.int64) * per, total)
    hi = np.minimum(lo + per, total)
    return np.stack([lo, hi], axis=1)
