"""Sorted Neighborhood blocking (Kolb/Thor/Rahm, arXiv:1010.3053).

The other canonical ER search-space reduction: entities are sorted by a
key and every pair within a sliding window of size ``w`` over the sort
order is compared — pair set {(i, j) : 0 < j − i ≤ w − 1} over sorted
positions, the *band* of width w − 1 above the diagonal. Unlike standard
blocking there is no block distribution to skew: the band's pair count is
a pure function of (n, w), so the paper's load-balancing discipline
reduces to an exact range partition of the band's pair-index space
(the PairRange treatment applied to the band instead of blocks).

Enumeration is row-major over the band: sorted row ``i`` holds
``c_i = min(w − 1, n − 1 − i)`` pairs ``(i, i+1) .. (i, i+c_i)``. The
first ``n − w_eff + 1`` rows are *full* (w_eff − 1 pairs each,
w_eff = min(w, n)); the tail rows shrink 1-per-row — exactly the
column-major triangular enumeration of a block of size w_eff − 1, so the
closed-form inverse reuses :func:`core.enumeration.invert_cell_index`.

Closed forms (w_eff = min(w, n), nf = n − w_eff + 1 full rows):

    P        = (w_eff − 1)·n − w_eff·(w_eff − 1)/2
    S(i)     = i·(w_eff − 1)                            for i ≤ nf
             = nf·(w_eff − 1) + Σ_{k=nf}^{i−1}(n−1−k)   otherwise
    p(i, j)  = S(i) + (j − i − 1)

Range k ∩ band is a contiguous run of band cells: rows i_lo..i_hi with a
prefix cut at (i_lo, j_lo) and a suffix cut at (i_hi, j_hi) — the same
corner-cut shape PairRange's range/block segments have, which is what the
tile-catalog compiler consumes (er/executor.py). The per-range *gather
set* (sorted rows a reducer must read) is a union of ≤ 2 contiguous
intervals, giving an O(r) exact ``map_output_size`` (Fig. 12 analog).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from . import enumeration as en

__all__ = [
    "SortedNeighborhoodPlan",
    "plan_sorted_neighborhood",
    "band_pair_count",
    "band_row_start",
    "band_pair_index",
    "invert_band_index",
    "pairs_of_band_range",
    "band_range_segment",
    "band_range_intervals",
    "map_output_size",
]


def _w_eff(n: int, w: int) -> int:
    """Effective window: w clamped to n (w ≥ n ⇒ the full triangle)."""
    return int(min(max(w, 1), max(n, 1)))


def band_pair_count(n: int, w: int) -> int:
    """|{(i, j) : 0 < j − i ≤ w − 1, 0 ≤ i < j < n}|."""
    we = _w_eff(n, w)
    if n < 2 or we < 2:
        return 0
    return (we - 1) * n - we * (we - 1) // 2


def band_row_start(i, n: int, w: int):
    """S(i): number of band pairs in sorted rows < i. Vectorized over i."""
    we = _w_eff(n, w)
    i = np.asarray(i, np.int64)
    nf = n - we + 1                      # rows 0..nf−1 are full (we−1 pairs)
    full = np.minimum(i, nf) * (we - 1)
    t = np.maximum(i - nf, 0)            # tail rows consumed
    # tail row nf+u holds we−2−u pairs: arithmetic series sum
    tail = t * (2 * (we - 2) - (t - 1)) // 2
    return full + tail


def band_pair_index(i, j, n: int, w: int):
    """Global band-pair index of (i, j), 0 < j − i ≤ w_eff − 1."""
    i = np.asarray(i, np.int64)
    j = np.asarray(j, np.int64)
    return band_row_start(i, n, w) + (j - i - 1)


def invert_band_index(p, n: int, w: int):
    """Inverse of :func:`band_pair_index`: p → (i, j). Vectorized over p.

    Full rows invert by divmod; tail rows are the triangular enumeration
    of a block of size w_eff − 1 shifted to start at row nf (docstring
    above), inverted with the exact :func:`enumeration.invert_cell_index`.
    """
    we = _w_eff(n, w)
    p = np.asarray(p, np.int64)
    nf = n - we + 1
    head = nf * (we - 1)
    in_full = p < head
    pc = np.where(in_full, p, 0)
    i_full = pc // max(we - 1, 1)
    j_full = i_full + 1 + pc % max(we - 1, 1)
    q = np.where(in_full, 0, p - head)
    x, y = en.invert_cell_index(q, np.int64(max(we - 1, 2)))
    return (np.where(in_full, i_full, nf + x),
            np.where(in_full, j_full, nf + y))


@dataclass(frozen=True)
class SortedNeighborhoodPlan:
    """Range partition of the window-w band over n sorted entities."""
    n: int
    w: int                     # requested window (w_eff = min(w, n) applies)
    r: int
    bounds: np.ndarray         # (r, 2) [lo, hi) band-pair-index bounds
    total_pairs: int

    @property
    def w_eff(self) -> int:
        return _w_eff(self.n, self.w)

    @property
    def reducer_pairs(self) -> np.ndarray:
        return (self.bounds[:, 1] - self.bounds[:, 0]).astype(np.int64)


def plan_sorted_neighborhood(n: int, w: int, r: int) -> SortedNeighborhoodPlan:
    """Balance the band over r reduce tasks: Alg. 2's ceil split of the
    pair-index space — exact by construction (max/mean ≤ ceil/floor)."""
    total = band_pair_count(n, w)
    return SortedNeighborhoodPlan(
        n=int(n), w=int(w), r=int(r),
        bounds=en.range_bounds(total, r), total_pairs=total)


def pairs_of_band_range(plan: SortedNeighborhoodPlan, k: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize range k's pairs as (rows_a, rows_b) sorted positions."""
    lo, hi = map(int, plan.bounds[k])
    p = np.arange(lo, hi, dtype=np.int64)
    return invert_band_index(p, plan.n, plan.w)


def band_range_segment(plan: SortedNeighborhoodPlan, k: int
                       ) -> Tuple[int, int, int, int] | None:
    """Range k as a corner-cut band segment (i_lo, j_lo, i_hi, j_hi):
    rows i_lo..i_hi of the band, prefix-cut before (i_lo, j_lo), suffix-cut
    after (i_hi, j_hi). None if the range is empty."""
    lo, hi = map(int, plan.bounds[k])
    if hi <= lo:
        return None
    i_lo, j_lo = (int(v) for v in invert_band_index(np.int64(lo), plan.n, plan.w))
    i_hi, j_hi = (int(v) for v in invert_band_index(np.int64(hi - 1), plan.n, plan.w))
    return i_lo, j_lo, i_hi, j_hi


def band_range_intervals(plan: SortedNeighborhoodPlan, k: int
                         ) -> List[Tuple[int, int]]:
    """Gather set of range k — the sorted rows appearing in any of its
    pairs — as ≤ 2 disjoint [lo, hi]-inclusive intervals.

    Rows i_lo..i_hi are all present; columns of every row past the first
    start at i+1 ≤ i_hi+1, so rows ∪ those columns is one contiguous
    interval; only the first row's prefix-cut columns [j_lo, …] can
    detach (range starts deep inside row i_lo).
    """
    seg = band_range_segment(plan, k)
    if seg is None:
        return []
    i_lo, j_lo, i_hi, j_hi = seg
    n, we = plan.n, plan.w_eff
    if i_lo == i_hi:
        if j_lo <= i_lo + 1:
            return [(i_lo, j_hi)]
        return [(i_lo, i_lo), (j_lo, j_hi)]
    # columns of rows i_lo+1..i_hi: [i_lo+2, e_mid] ∪ [i_hi+1, j_hi] —
    # contiguous with the row interval [i_lo, i_hi].
    e_mid = min(i_hi - 1 + we - 1, n - 1) if i_hi > i_lo + 1 else i_hi
    base_hi = max(i_hi, e_mid, j_hi)
    e_first = min(i_lo + we - 1, n - 1)   # first row's columns [j_lo, e_first]
    if j_lo <= base_hi + 1:
        return [(i_lo, max(base_hi, e_first))]
    return [(i_lo, base_hi), (j_lo, e_first)]


def map_output_size(plan: SortedNeighborhoodPlan) -> int:
    """kv-pairs emitted by map (Fig. 12 analog): Σ over ranges of the
    gather-set size — O(r) via the ≤ 2-interval bound, exact at any scale."""
    total = 0
    for k in range(plan.r):
        for lo, hi in band_range_intervals(plan, k):
            total += hi - lo + 1
    return total
