"""Block Distribution Matrix (paper §III-B, Alg. 3).

Job 1 of the paper's workflow: count entities per (block, input partition).
The BDM is tiny (b × m int64) and is the *only* state the load-balancing
strategies need — BlockSplit's match-task table and PairRange's ranges are
deterministic functions of it, which is also the fault-tolerance story: a
restarted worker recomputes its plan from the checkpointed BDM.

Two implementations:
  * :func:`compute_bdm` — numpy, host-side (planning path).
  * :func:`compute_bdm_jnp` — jnp, jit-able (used inside the shard_map
    distributed job where each device bincounts its local shard; the
    cross-device reduction is a psum/all_gather in er/distributed.py).

Entity indexing (paper §V, Fig. 6 "white numbers"): entity e in partition
Π_i, block Φ_k gets global index = (# entities of Φ_k in Π_0..Π_{i-1}) +
(rank of e among Φ_k-entities within Π_i, in input order). This is the
paper's map-side local enumeration enabled by the BDM.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "compute_bdm",
    "compute_bdm_jnp",
    "update_bdm",
    "entity_indices",
    "entity_indices_jnp",
    "blocked_layout",
]


def compute_bdm(block_ids: np.ndarray, partition_ids: np.ndarray,
                num_blocks: int, num_partitions: int) -> np.ndarray:
    """BDM[k, i] = |{e : block(e)=k, partition(e)=i}| (b × m int64)."""
    flat = np.asarray(block_ids, np.int64) * num_partitions + np.asarray(partition_ids, np.int64)
    counts = np.bincount(flat, minlength=num_blocks * num_partitions)
    return counts.reshape(num_blocks, num_partitions).astype(np.int64)


def update_bdm(bdm: np.ndarray, block_ids: np.ndarray,
               partition_ids: np.ndarray,
               num_blocks: int | None = None) -> np.ndarray:
    """Incremental Job 1: fold a new entity batch into an existing BDM.

    Because the BDM is a pure per-(block, partition) count, it is a monoid
    under elementwise addition — ``update_bdm(compute_bdm(A), B) ==
    compute_bdm(A ++ B)`` for any split, which is what lets a resident
    service absorb query micro-batches without replanning Job 1 from
    scratch. Never-seen blocks grow the matrix by appending zero rows
    (block ids must stay dense); ``num_blocks`` forces growth to at least
    that many rows even when the batch is empty. The partition count is
    pinned to ``bdm.shape[1]``. Returns a new (b', m) int64 matrix with
    b' >= bdm.shape[0]; the input is never mutated.
    """
    bdm = np.asarray(bdm, np.int64)
    b, m = bdm.shape
    block_ids = np.asarray(block_ids, np.int64)
    partition_ids = np.asarray(partition_ids, np.int64)
    nb = max(b, num_blocks or 0,
             int(block_ids.max()) + 1 if block_ids.size else 0)
    out = np.zeros((nb, m), np.int64)
    out[:b] = bdm
    if block_ids.size:
        out += compute_bdm(block_ids, partition_ids, nb, m)
    return out


def compute_bdm_jnp(block_ids, partition_ids, num_blocks: int, num_partitions: int):
    """jnp twin of :func:`compute_bdm` (jit-able; static b, m)."""
    import jax.numpy as jnp

    flat = block_ids.astype(jnp.int32) * num_partitions + partition_ids.astype(jnp.int32)
    counts = jnp.bincount(flat, length=num_blocks * num_partitions)
    return counts.reshape(num_blocks, num_partitions)


def _cumcount_by_key(key: np.ndarray) -> np.ndarray:
    """rank[e] = #{e' < e (input order) : key[e'] == key[e]} — vectorized."""
    n = key.shape[0]
    if n == 0:
        return np.zeros(0, np.int64)
    order = np.argsort(key, kind="stable")  # groups keys, preserves input order
    sorted_key = key[order]
    new_group = np.empty(n, bool)
    new_group[0] = True
    np.not_equal(sorted_key[1:], sorted_key[:-1], out=new_group[1:])
    group_start = np.maximum.accumulate(np.where(new_group, np.arange(n), 0))
    rank_sorted = np.arange(n) - group_start
    rank = np.empty(n, np.int64)
    rank[order] = rank_sorted
    return rank


def entity_indices(block_ids: np.ndarray, partition_ids: np.ndarray,
                   bdm: np.ndarray) -> np.ndarray:
    """Global per-block entity index x for every entity (paper Fig. 6)."""
    b, m = bdm.shape
    block_ids = np.asarray(block_ids, np.int64)
    partition_ids = np.asarray(partition_ids, np.int64)
    # offset[k, i] = # entities of block k in partitions < i  (exclusive cumsum)
    offs = np.concatenate([np.zeros((b, 1), np.int64), np.cumsum(bdm, axis=1)[:, :-1]], axis=1)
    base = offs[block_ids, partition_ids]
    rank = _cumcount_by_key(block_ids * m + partition_ids)
    return base + rank


def entity_indices_jnp(block_ids, partition_ids, bdm):
    """jnp twin of :func:`entity_indices` (jit-able)."""
    import jax.numpy as jnp

    b, m = bdm.shape
    n = block_ids.shape[0]
    offs = jnp.concatenate(
        [jnp.zeros((b, 1), bdm.dtype), jnp.cumsum(bdm, axis=1)[:, :-1]], axis=1)
    base = offs[block_ids, partition_ids]
    key = block_ids.astype(jnp.int32) * m + partition_ids.astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    iota = jnp.arange(n, dtype=jnp.int32)
    new_group = jnp.concatenate(
        [jnp.ones(1, bool), sorted_key[1:] != sorted_key[:-1]])
    group_start = jax_cummax(jnp.where(new_group, iota, 0))
    rank_sorted = iota - group_start
    rank = jnp.zeros(n, jnp.int32).at[order].set(rank_sorted)
    return base + rank


def jax_cummax(x):
    from jax import lax

    return lax.cummax(x)


def blocked_layout(block_ids: np.ndarray, entity_idx: np.ndarray,
                   block_sizes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Permutation into the canonical blocked layout.

    Row ``estart[k] + x`` holds the entity with (block k, index x), where
    ``estart`` is the exclusive cumsum of block sizes. Returns
    ``(perm, estart)`` with ``perm[target_row] = source_row``.
    """
    sizes = np.asarray(block_sizes, np.int64)
    estart = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)[:-1]])
    target = estart[np.asarray(block_ids, np.int64)] + np.asarray(entity_idx, np.int64)
    perm = np.empty(target.shape[0], np.int64)
    perm[target] = np.arange(target.shape[0])
    return perm, estart
