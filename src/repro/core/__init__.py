"""Core contribution of Kolb/Thor/Rahm 2011: skew-aware load balancing for
blocked pairwise workloads — BDM, Basic, BlockSplit, PairRange, and the
two-source extension, adapted to static-shape SPMD execution on TPU meshes.
"""
from . import enumeration  # noqa: F401
from .assignment import greedy_lpt, greedy_lpt_jnp, makespan_stats  # noqa: F401
from .basic import BasicPlan, plan_basic  # noqa: F401
from .bdm import (  # noqa: F401
    blocked_layout,
    compute_bdm,
    compute_bdm_jnp,
    entity_indices,
    entity_indices_jnp,
    update_bdm,
)
from .block_split import BlockSplitPlan, plan_block_split  # noqa: F401
from .sorted_neighborhood import (  # noqa: F401
    SortedNeighborhoodPlan,
    band_pair_count,
    pairs_of_band_range,
    plan_sorted_neighborhood,
)
from .pair_range import (  # noqa: F401
    PairRangePlan,
    entity_range_matrix,
    map_output_size,
    pairs_of_range,
    pairs_of_range_jnp,
    plan_pair_range,
    range_block_intervals,
)
from .two_source import (  # noqa: F401
    BlockSplit2Plan,
    PairRange2Plan,
    TwoSourceBDM,
    pairs_of_range_2src,
    plan_block_split_2src,
    plan_pair_range_2src,
    range_block_segments_2src,
)
