"""The Basic strategy (paper §III): key-partitioned blocks, no skew handling.

Every block goes in full to one reduce task, chosen by hashing the blocking
key (Hadoop's default HashPartitioner ≡ ``block_index mod r`` once keys are
dense indices). This is the paper's baseline and the one that collapses on
skew: the largest block's pair count lower-bounds the makespan.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import enumeration as en

__all__ = ["BasicPlan", "plan_basic"]


@dataclass(frozen=True)
class BasicPlan:
    """block -> reduce task, plus per-reducer pair loads."""
    r: int
    block_sizes: np.ndarray      # (b,)
    block_reducer: np.ndarray    # (b,)
    reducer_pairs: np.ndarray    # (r,)
    total_pairs: int

    # Every entity is emitted exactly once (no replication) — Fig. 12.
    def map_output_size(self) -> int:
        return int(self.block_sizes.sum())


def plan_basic(bdm: np.ndarray, r: int, salt: int = 0) -> BasicPlan:
    sizes = bdm.sum(axis=1).astype(np.int64)
    pairs = en.block_pair_counts(sizes)
    # Dense block indices stand in for key hashes; `salt` lets benchmarks
    # explore hash-placement luck (the Fig. 10 peaks).
    reducer = (np.arange(sizes.shape[0], dtype=np.int64) + salt) % r
    loads = np.bincount(reducer, weights=pairs, minlength=r).astype(np.int64)
    return BasicPlan(
        r=r,
        block_sizes=sizes,
        block_reducer=reducer,
        reducer_pairs=loads,
        total_pairs=int(pairs.sum()),
    )
