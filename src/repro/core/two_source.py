"""Two-source matching R × S (paper Appendix I).

Per block k only cross-source pairs (e_R, e_S) are compared; the cell
enumeration becomes row-major rectangular: c(x, y, N_S) = x*N_S + y, with
o(i) = sum_{k<i} |Φ_k,R|*|Φ_k,S| (the paper prints a stray "-1"; dropping it
matches Fig. 15(b)). BlockSplit restricts cross tasks to Π_i ∈ R, Π_j ∈ S.

Entities without blocking keys (paper §III / App. I preamble) are handled by
the decomposition match_B(R,S) = match_B(R-R0, S-S0) ∪ match_⊥(R, S0) ∪
match_⊥(R0, S-S0) — implemented in er/pipeline.py by synthesizing a
constant blocking key for the ⊥ jobs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from . import enumeration as en
from .assignment import greedy_lpt

__all__ = [
    "TwoSourceBDM",
    "BlockSplit2Plan",
    "PairRange2Plan",
    "plan_block_split_2src",
    "plan_pair_range_2src",
    "pairs_of_range_2src",
    "range_block_segments_2src",
]


@dataclass(frozen=True)
class TwoSourceBDM:
    """Per-source BDMs over a shared dense block-index space."""
    bdm_r: np.ndarray  # (b, m_r)
    bdm_s: np.ndarray  # (b, m_s)

    @property
    def sizes_r(self) -> np.ndarray:
        return self.bdm_r.sum(axis=1).astype(np.int64)

    @property
    def sizes_s(self) -> np.ndarray:
        return self.bdm_s.sum(axis=1).astype(np.int64)


@dataclass(frozen=True)
class BlockSplit2Plan:
    r: int
    task_block: np.ndarray
    task_i: np.ndarray           # partition in R (-1: unsplit)
    task_j: np.ndarray           # partition in S (-1: unsplit)
    task_pairs: np.ndarray
    task_reducer: np.ndarray
    reducer_pairs: np.ndarray
    # Geometry: row intervals in the per-source blocked layouts.
    task_a_start: np.ndarray     # rows in R layout
    task_a_len: np.ndarray
    task_b_start: np.ndarray     # rows in S layout
    task_b_len: np.ndarray
    total_pairs: int
    n_rows_r: int = 0            # total rows in the R blocked layout
    n_rows_s: int = 0            # total rows in the S blocked layout


def plan_block_split_2src(bdm2: TwoSourceBDM, r: int) -> BlockSplit2Plan:
    br, bs = np.asarray(bdm2.bdm_r, np.int64), np.asarray(bdm2.bdm_s, np.int64)
    b, m_r = br.shape
    _, m_s = bs.shape
    sr, ss = br.sum(axis=1), bs.sum(axis=1)
    pairs = sr * ss
    total = int(pairs.sum())
    avg = total / r if r else 0.0

    er_start = np.concatenate([np.zeros(1, np.int64), np.cumsum(sr)[:-1]])
    es_start = np.concatenate([np.zeros(1, np.int64), np.cumsum(ss)[:-1]])
    sub_r = np.concatenate([np.zeros((b, 1), np.int64), np.cumsum(br, axis=1)[:, :-1]], axis=1)
    sub_s = np.concatenate([np.zeros((b, 1), np.int64), np.cumsum(bs, axis=1)[:, :-1]], axis=1)

    t_block, t_i, t_j, t_pairs = [], [], [], []
    a0, al, b0, bl = [], [], [], []
    for k in range(b):
        if pairs[k] == 0:
            continue
        if pairs[k] <= avg:
            t_block.append(k); t_i.append(-1); t_j.append(-1)
            t_pairs.append(int(pairs[k]))
            a0.append(int(er_start[k])); al.append(int(sr[k]))
            b0.append(int(es_start[k])); bl.append(int(ss[k]))
        else:
            for i in range(m_r):
                ni = int(br[k, i])
                if ni == 0:
                    continue
                for j in range(m_s):
                    nj = int(bs[k, j])
                    if nj == 0:
                        continue
                    t_block.append(k); t_i.append(i); t_j.append(j)
                    t_pairs.append(ni * nj)
                    a0.append(int(er_start[k] + sub_r[k, i])); al.append(ni)
                    b0.append(int(es_start[k] + sub_s[k, j])); bl.append(nj)

    w = np.asarray(t_pairs, np.int64)
    assignment, loads = greedy_lpt(w, r)
    return BlockSplit2Plan(
        r=r,
        task_block=np.asarray(t_block, np.int64),
        task_i=np.asarray(t_i, np.int64),
        task_j=np.asarray(t_j, np.int64),
        task_pairs=w, task_reducer=assignment, reducer_pairs=loads,
        task_a_start=np.asarray(a0, np.int64), task_a_len=np.asarray(al, np.int64),
        task_b_start=np.asarray(b0, np.int64), task_b_len=np.asarray(bl, np.int64),
        total_pairs=total, n_rows_r=int(sr.sum()), n_rows_s=int(ss.sum()))


@dataclass(frozen=True)
class PairRange2Plan:
    r: int
    sizes_r: np.ndarray
    sizes_s: np.ndarray
    pair_counts: np.ndarray
    offsets: np.ndarray
    er_start: np.ndarray
    es_start: np.ndarray
    bounds: np.ndarray
    total_pairs: int

    @property
    def reducer_pairs(self) -> np.ndarray:
        return (self.bounds[:, 1] - self.bounds[:, 0]).astype(np.int64)

    @property
    def n_rows_r(self) -> int:
        return int(self.sizes_r.sum())

    @property
    def n_rows_s(self) -> int:
        return int(self.sizes_s.sum())


def plan_pair_range_2src(bdm2: TwoSourceBDM, r: int) -> PairRange2Plan:
    sr, ss = bdm2.sizes_r, bdm2.sizes_s
    pairs = en.block_pair_counts_2src(sr, ss)
    offsets, total = en.pair_offsets(pairs)
    er_start = np.concatenate([np.zeros(1, np.int64), np.cumsum(sr)[:-1]])
    es_start = np.concatenate([np.zeros(1, np.int64), np.cumsum(ss)[:-1]])
    return PairRange2Plan(
        r=r, sizes_r=sr, sizes_s=ss, pair_counts=pairs, offsets=offsets,
        er_start=er_start, es_start=es_start,
        bounds=en.range_bounds(total, r), total_pairs=total)


def pairs_of_range_2src(plan: PairRange2Plan, k: int):
    """Materialize range k's pairs: (block, x, y, row_r, row_s)."""
    lo, hi = plan.bounds[k]
    p = np.arange(lo, hi, dtype=np.int64)
    block = np.searchsorted(plan.offsets, p, side="right") - 1
    q = p - plan.offsets[block]
    x, y = en.invert_cell_index_2src(q, plan.sizes_s[block])
    return block, x, y, plan.er_start[block] + x, plan.es_start[block] + y


def range_block_segments_2src(plan: PairRange2Plan,
                              k: int) -> List[Tuple[int, int, int, int, int]]:
    """Per-block cell segments of range k: [(block, x_lo, y_lo, x_hi, y_hi)].

    Range k's pair-index interval [lo, hi) intersected with block ``blk``
    is a contiguous run of the row-major rectangular enumeration
    ``c(x, y) = x·N_S + y``: a prefix-cut first row, full middle rows, a
    suffix-cut last row — the rectangular analog of
    ``pair_range.range_block_segments``, and exactly what the tile-catalog
    compiler turns into lb/ub corner-cut predicates. O(1) per (range,
    block); only non-empty segments are returned, coordinates block-local.
    """
    lo, hi = map(int, plan.bounds[k])
    if hi <= lo:
        return []
    offsets, counts = plan.offsets, plan.pair_counts
    b_lo = int(np.searchsorted(offsets, lo, side="right")) - 1
    b_hi = int(np.searchsorted(offsets, hi - 1, side="right")) - 1
    out = []
    for blk in range(b_lo, b_hi + 1):
        npairs = int(counts[blk])
        if npairs == 0:
            continue
        qlo = max(lo - int(offsets[blk]), 0)
        qhi = min(hi - int(offsets[blk]), npairs) - 1
        if qhi < qlo:
            continue
        ns = int(plan.sizes_s[blk])
        out.append((blk, qlo // ns, qlo % ns, qhi // ns, qhi % ns))
    return out
