"""BlockSplit (paper §IV, Alg. 1).

Blocks whose pair count exceeds the average reducer workload P/r are split
along the m input partitions into sub-blocks; a split block k yields
  * m single-sub-block match tasks  k.i      (triangular work), and
  * m(m-1)/2 cross tasks            k.i×j    (rectangular work),
which together cover exactly the block's pair set. Tasks are assigned to
reduce tasks greedy-LPT (largest first). Entities of split blocks are
replicated once per non-empty partition of their block (paper footnote 3).

TPU mapping: our canonical *blocked layout* (core/bdm.blocked_layout) orders
each block's entities partition-major, so every sub-block is a contiguous
row interval. A match task therefore compiles to a static geometry record

    (a_start, a_len, b_start, b_len, triangular)

— a triangular tile for k.i / unsplit blocks (a == b) or a rectangular tile
for k.i×j — which is exactly what the pair-similarity kernel consumes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import enumeration as en
from .assignment import greedy_lpt

__all__ = ["BlockSplitPlan", "plan_block_split"]


@dataclass(frozen=True)
class BlockSplitPlan:
    r: int
    m: int
    bdm: np.ndarray              # (b, m)
    block_sizes: np.ndarray      # (b,)
    split_mask: np.ndarray       # (b,) bool — block was split
    # Match-task table (t tasks):
    task_block: np.ndarray       # (t,)
    task_i: np.ndarray           # (t,)  -1 for unsplit whole-block tasks
    task_j: np.ndarray           # (t,)  -1 for unsplit; j <= i for cross
    task_pairs: np.ndarray       # (t,)
    task_reducer: np.ndarray     # (t,)
    reducer_pairs: np.ndarray    # (r,)
    # Tile geometry in the blocked layout:
    task_a_start: np.ndarray     # (t,)
    task_a_len: np.ndarray       # (t,)
    task_b_start: np.ndarray     # (t,)
    task_b_len: np.ndarray       # (t,)
    task_triangular: np.ndarray  # (t,) bool
    total_pairs: int

    def map_output_size(self) -> int:
        """kv-pairs emitted by map (Fig. 12): 1 per entity of an unsplit
        block with >=1 pair, (#non-empty partitions) per entity of a split
        block. Entities of singleton blocks are dropped (no pairs)."""
        sizes = self.block_sizes
        nonempty = (self.bdm > 0).sum(axis=1)
        unsplit = (~self.split_mask) & (sizes > 1)
        return int(sizes[unsplit].sum()
                   + (sizes[self.split_mask] * nonempty[self.split_mask]).sum())


def plan_block_split(bdm: np.ndarray, r: int) -> BlockSplitPlan:
    bdm = np.asarray(bdm, np.int64)
    b, m = bdm.shape
    sizes = bdm.sum(axis=1)
    pairs = en.block_pair_counts(sizes)
    total = int(pairs.sum())
    avg = total / r if r else 0.0

    split_mask = pairs > avg  # paper: strict '>' (Alg. 1 line 10 is '<=')

    estart = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)[:-1]])
    sub_off = np.concatenate(
        [np.zeros((b, 1), np.int64), np.cumsum(bdm, axis=1)[:, :-1]], axis=1)

    t_block, t_i, t_j, t_pairs = [], [], [], []
    a_start, a_len, b_start, b_len, tri = [], [], [], [], []

    # Unsplit blocks with at least one pair: one triangular task each.
    for k in np.flatnonzero((~split_mask) & (pairs > 0)):
        t_block.append(k); t_i.append(-1); t_j.append(-1)
        t_pairs.append(int(pairs[k]))
        a_start.append(int(estart[k])); a_len.append(int(sizes[k]))
        b_start.append(int(estart[k])); b_len.append(int(sizes[k]))
        tri.append(True)

    # Split blocks: k.i (triangular) and k.i×j, i > j (rectangular).
    for k in np.flatnonzero(split_mask):
        for i in range(m):
            ni = int(bdm[k, i])
            if ni == 0:
                continue
            # Alg. 1 line 16 keeps k.i even for singleton sub-blocks
            # (0 pairs) — the entity is still routed to it.
            t_block.append(k); t_i.append(i); t_j.append(i)
            t_pairs.append(ni * (ni - 1) // 2)
            s = int(estart[k] + sub_off[k, i])
            a_start.append(s); a_len.append(ni)
            b_start.append(s); b_len.append(ni)
            tri.append(True)
            for j in range(i):
                nj = int(bdm[k, j])
                if nj == 0:
                    continue
                t_block.append(k); t_i.append(i); t_j.append(j)
                t_pairs.append(ni * nj)
                a_start.append(int(estart[k] + sub_off[k, i])); a_len.append(ni)
                b_start.append(int(estart[k] + sub_off[k, j])); b_len.append(nj)
                tri.append(False)

    task_pairs = np.asarray(t_pairs, np.int64)
    assignment, loads = greedy_lpt(task_pairs, r)

    return BlockSplitPlan(
        r=r, m=m, bdm=bdm,
        block_sizes=sizes, split_mask=split_mask,
        task_block=np.asarray(t_block, np.int64),
        task_i=np.asarray(t_i, np.int64),
        task_j=np.asarray(t_j, np.int64),
        task_pairs=task_pairs,
        task_reducer=assignment,
        reducer_pairs=loads,
        task_a_start=np.asarray(a_start, np.int64),
        task_a_len=np.asarray(a_len, np.int64),
        task_b_start=np.asarray(b_start, np.int64),
        task_b_len=np.asarray(b_len, np.int64),
        task_triangular=np.asarray(tri, bool),
        total_pairs=total,
    )
