"""Serving: batched prefill + autoregressive decode.

``serve_step`` for the dry-run shapes is exactly ``make_decode_step``'s
returned function: one new token per sequence against a seq_len KV cache
(decode_32k / long_500k cells) — NOT a train_step. ``generate`` wraps
prefill + a ``lax.scan`` of decode steps for the examples/smoke tests
(greedy or temperature sampling).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import get_model
from ..models.config import ModelConfig

__all__ = ["make_prefill", "make_decode_step", "generate"]


def make_prefill(cfg: ModelConfig) -> Callable:
    mod = get_model(cfg)

    def prefill(params, batch, cache):
        return mod.prefill(params, batch, cfg, cache)

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    mod = get_model(cfg)

    def decode_step(params, tokens, cache):
        return mod.decode_step(params, tokens, cache, cfg)

    return decode_step


def generate(params, cfg: ModelConfig, batch: Dict, max_new_tokens: int,
             temperature: float = 0.0, key=None,
             cache_len: Optional[int] = None) -> jnp.ndarray:
    """Greedy/temperature generation. batch must contain 'tokens' (B, S)
    (+ modality extras). Returns (B, max_new_tokens) int32."""
    mod = get_model(cfg)
    b, s = batch["tokens"].shape
    prefix = cfg.n_patches if cfg.family == "vlm" else 0  # prefill writes it
    cache = mod.init_cache(cfg, b, cache_len or (s + prefix + max_new_tokens))
    logits, cache = mod.prefill(params, batch, cfg, cache)
    if key is None:
        key = jax.random.key(0)

    def sample(logits, k):
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            k, logits[:, -1].astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)

    tok0 = sample(logits, key)

    def step(carry, k):
        tok, cache = carry
        logits, cache = mod.decode_step(params, tok[:, None], cache, cfg)
        nxt = sample(logits, k)
        return (nxt, cache), tok

    keys = jax.random.split(key, max_new_tokens)
    (last, _), toks = jax.lax.scan(step, (tok0, cache), keys)
    out = jnp.concatenate([toks.T, last[:, None]], axis=1)  # (B, T+1)
    return out[:, :max_new_tokens]
