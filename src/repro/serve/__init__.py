"""Serving namespace: LM decode loop plus the resident entity-resolution
match service (the ER analog of a decode server — ingest once, answer
micro-batches from a warm compiled-shape cache)."""
from ..er.service import ERService, ServiceConfig, compile_counter  # noqa: F401
from .decode import generate, make_decode_step, make_prefill  # noqa: F401
