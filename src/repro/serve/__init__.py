from .decode import generate, make_decode_step, make_prefill  # noqa: F401
