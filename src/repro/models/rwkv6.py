"""RWKV-6 "Finch" (attention-free, data-dependent decay) — arXiv:2404.05892.

Per layer: time-mix (the WKV recurrence) + channel-mix. The WKV state is
one (H, hd, hd) matrix per head, updated per token as

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

with data-dependent per-channel decay w_t = exp(−exp(wx_t)) (the Finch
contribution; we implement the decay projection without the paper's
low-rank LoRA factorization — noted in DESIGN.md). Training runs the
recurrence with ``lax.scan`` over time (a chunked block-parallel form is
a §Perf candidate); decode carries the state — O(1) per token, which is
what qualifies this arch for the 500k-token long-context shape.

Token-shift mixes x_{t-1} into the projections (standard RWKV); the
shift uses ``jnp.roll``+zero for training and the cached last-x for
decode.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..sharding import act_constrain, constrain
from .config import ModelConfig
from .layers import dense_init, dtype_of, rms_norm, stack_layers

__all__ = ["init", "forward", "init_cache", "prefill", "decode_step"]


def _init_layer(key, cfg: ModelConfig):
    d = cfg.d_model
    f = cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    return {
        "ln_tm": jnp.ones((d,), dt),
        # token-shift mix coefficients per projection
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_g": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "w_r": dense_init(ks[0], (d, d), dt),
        "w_k": dense_init(ks[1], (d, d), dt),
        "w_v": dense_init(ks[2], (d, d), dt),
        "w_g": dense_init(ks[3], (d, d), dt),
        "w_decay": dense_init(ks[4], (d, d), dt, scale=0.01),
        "w_decay_b": jnp.full((d,), -6.0, dt),   # exp(-exp(-6)) ≈ slow decay
        "u_bonus": jnp.zeros((cfg.n_heads, cfg.hd), dt),
        "ln_x": jnp.ones((d,), dt),              # per-head group norm approx
        "w_wkv_out": dense_init(ks[5], (d, d), dt),
        "ln_cm": jnp.ones((d,), dt),
        "mu_ck": jnp.full((d,), 0.5, dt),
        "cm_k": dense_init(ks[6], (d, f), dt),
        "cm_v": dense_init(ks[7], (f, d), dt),
        "cm_r": dense_init(ks[8], (d, d), dt),
    }


def init(cfg: ModelConfig, key) -> Dict:
    dt = dtype_of(cfg.param_dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    return {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "layers": stack_layers(lambda k: _init_layer(k, cfg), k_layers, cfg.n_layers),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "head": dense_init(k_head, (cfg.d_model, cfg.vocab), dt),
    }


def _shift(x, last=None):
    """x: (B, S, d) → x_{t-1} (zero / cached at t=0)."""
    prev = jnp.roll(x, 1, axis=1)
    init = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return prev.at[:, 0].set(init[:, 0])


_WKV_CHUNK = 256


def _wkv_scan(r, k, v, w, u, state):
    """r/k/v: (B, S, H, hd); w: (B, S, H, hd) decay in (0,1);
    u: (H, hd) bonus. state: (B, H, hd, hd) f32. Returns (y, state).

    Two-level scan with rematted chunks: a flat time scan's backward
    saves the (B, H, hd, hd) state at EVERY step — ~86 GB/layer at the
    train_4k cell. Chunking saves only S/256 boundary states and
    recomputes inside the chunk (the standard linear-RNN training
    memory/compute trade)."""

    def step(s, inp):
        rt, kt, vt, wt = inp                      # (B, H, hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)  # outer product
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       s.astype(rt.dtype) + u[None, :, :, None] * kv)
        s = wt.astype(s.dtype)[..., None] * s + kv.astype(s.dtype)
        return s, y

    seq = r.shape[1]
    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))  # (S, B, H, hd)
    if seq <= _WKV_CHUNK or seq % _WKV_CHUNK:
        state, ys = jax.lax.scan(step, state, xs)
        return ys.transpose(1, 0, 2, 3), state    # (B, S, H, hd)

    nc = seq // _WKV_CHUNK
    xs_c = tuple(t.reshape((nc, _WKV_CHUNK) + t.shape[1:]) for t in xs)

    def chunk(s, inp):
        return jax.lax.scan(step, s, inp)

    chunk = jax.checkpoint(chunk, policy=jax.checkpoint_policies.nothing_saveable)
    state, ys = jax.lax.scan(chunk, state, xs_c)
    ys = ys.reshape((seq,) + ys.shape[2:])
    return ys.transpose(1, 0, 2, 3), state


def _time_mix(p, x, cfg: ModelConfig, state, last_x):
    b, s, d = x.shape
    h_, hd = cfg.n_heads, cfg.hd
    xs = _shift(x, last_x)
    mix = lambda mu: x * mu + xs * (1 - mu)
    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["w_r"])
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["w_k"])
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["w_v"])
    g = jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["w_g"])
    wx = jnp.einsum("bsd,de->bse", mix(p["mu_w"]), p["w_decay"]) + p["w_decay_b"]
    w = jnp.exp(-jnp.exp(wx.astype(jnp.float32))).astype(x.dtype)
    shp = (b, s, h_, hd)
    y, state = _wkv_scan(r.reshape(shp), k.reshape(shp), v.reshape(shp),
                         w.reshape(shp), p["u_bonus"], state)
    y = y.astype(x.dtype)   # keep the layer carry in the compute dtype
    y = rms_norm(y.reshape(b, s, d), p["ln_x"], cfg.rms_eps)
    y = y * jax.nn.silu(g)
    return jnp.einsum("bsd,de->bse", y, p["w_wkv_out"]), state, x[:, -1]


def _channel_mix(p, x, cfg: ModelConfig, last_x):
    xs = _shift(x, last_x)
    xk = x * p["mu_ck"] + xs * (1 - p["mu_ck"])
    k = jnp.einsum("bsd,df->bsf", xk, p["cm_k"])
    kv = jnp.einsum("bsf,fd->bsd", jnp.square(jax.nn.relu(k)), p["cm_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xk, p["cm_r"]))
    return r * kv, x[:, -1]


def _layer(x, p, cfg: ModelConfig, state, last_tm, last_cm):
    h = rms_norm(x, p["ln_tm"], cfg.rms_eps)
    y, state, new_tm = _time_mix(p, h, cfg, state, last_tm)
    x = act_constrain(x + y, cfg.act_shard)
    h = rms_norm(x, p["ln_cm"], cfg.rms_eps)
    y, new_cm = _channel_mix(p, h, cfg, last_cm)
    return act_constrain(x + y, cfg.act_shard), state, new_tm, new_cm


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    """RWKV cache is O(1) in sequence length: the WKV state + the two
    token-shift last-x vectors, per layer."""
    del max_len
    dt = dtype_of(cfg.compute_dtype)
    L, b, d = cfg.n_layers, batch_size, cfg.d_model
    return {
        "wkv": jnp.zeros((L, b, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32),
        "tm_x": jnp.zeros((L, b, d), dt),
        "cm_x": jnp.zeros((L, b, d), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def _trunk(params, h, cfg: ModelConfig, cache):
    def body(carry, inp):
        x = carry
        p, st, ltm, lcm = inp
        x, st, ntm, ncm = _layer(x, p, cfg, st, ltm, lcm)
        return x, (st, ntm, ncm)

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, (st, tm, cm) = jax.lax.scan(
        body_fn, h,
        (params["layers"], cache["wkv"], cache["tm_x"], cache["cm_x"]),
        unroll=cfg.scan_unroll(cfg.n_layers))
    return h, {"wkv": st, "tm_x": tm, "cm_x": cm,
               "pos": cache["pos"] + h.shape[1]}


def forward(params, batch, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(dt)
    cache = init_cache(cfg, h.shape[0], 0)
    h, _ = _trunk(params, h, cfg, cache)
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))


def prefill(params, batch, cfg: ModelConfig, cache):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(dt)
    h, cache = _trunk(params, h, cfg, cache)
    h = rms_norm(h[:, -1:], params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype)), cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][tokens].astype(dt)    # (B, 1, d)
    h, cache = _trunk(params, h, cfg, cache)
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype)), cache
