"""Family → module dispatch. Every family exposes the same functional
surface (init / forward / init_cache / prefill / decode_step)."""
from __future__ import annotations

from types import ModuleType

from . import moe, rwkv6, transformer, whisper, zamba2
from .config import ModelConfig

MODEL_FAMILIES = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": rwkv6,
    "hybrid": zamba2,
    "audio": whisper,
}


def get_model(cfg: ModelConfig) -> ModuleType:
    try:
        return MODEL_FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None
