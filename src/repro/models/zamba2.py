"""Zamba2 hybrid: Mamba2 (SSD) backbone + one *shared* attention block
applied every ``shared_attn_every`` layers — arXiv:2411.15242.

Mamba2 layer (state-space duality, scalar-per-head A):
    xBC = causal_conv1d(in_proj_x(x))           (kernel 4, depthwise)
    h_t = exp(−Δ_t·exp(A_log)) · h_{t−1} + Δ_t · B_t ⊗ x_t
    y_t = C_t · h_t + D · x_t ;  out = out_proj(y · silu(z))
State per head: (head_dim, d_state) → decode is O(1) in context length,
which qualifies the arch for the 500k long-context shape.

The shared transformer block reuses ONE parameter set at every
application (Zamba's weight-sharing trick; we omit the paper's per-
invocation LoRA deltas and the concat-with-embedding input — recorded in
DESIGN.md §Assumptions). Structure: scan over ``n_layers/every`` super-
blocks; each = inner scan over ``every`` mamba layers + the shared attn.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..sharding import act_constrain, constrain
from .config import ModelConfig
from .layers import (apply_rope, dense_init, dtype_of, gqa_attention,
                     gqa_attention_cached, rms_norm, rope_tables,
                     stack_layers, swiglu)

__all__ = ["init", "forward", "init_cache", "prefill", "decode_step"]


# ---------------------------------------------------------------------------
# Mamba2 layer
# ---------------------------------------------------------------------------

def _init_mamba(key, cfg: ModelConfig):
    d, di, ds = cfg.d_model, cfg.inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    kconv = cfg.conv_kernel
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d_xbc = di + 2 * ds                     # x, B, C share the conv
    return {
        "ln": jnp.ones((d,), dt),
        "in_proj": dense_init(ks[0], (d, d_xbc + di + nh), dt),
        "conv_w": dense_init(ks[1], (d_xbc, kconv), dt, scale=0.5),
        "conv_b": jnp.zeros((d_xbc,), dt),
        "A_log": jnp.zeros((nh,), dt),      # A = -exp(A_log) ≈ -1
        "D": jnp.ones((nh,), dt),
        "dt_bias": jnp.full((nh,), -2.0, dt),
        "out_proj": dense_init(ks[2], (di, d), dt),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, S, C) depthwise causal conv, kernel K. state: (B, K-1, C)
    prior context (decode). Returns (y, new_state)."""
    bsz, s, c = x.shape
    k = w.shape[1]
    pad = jnp.zeros((bsz, k - 1, c), x.dtype) if state is None else state
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, C)
    cols = [xp[:, i:i + s, :] * w[:, i] for i in range(k)]
    y = sum(cols) + b
    return jax.nn.silu(y), xp[:, -(k - 1):, :]


_SSD_CHUNK = 256


def _ssd_scan(xh, bmat, cmat, dt_, a, state):
    """xh: (B,S,H,hd); bmat/cmat: (B,S,ds); dt_: (B,S,H); a: (H,) <0;
    state: (B,H,hd,ds) f32. Single-group SSD recurrence.

    Chunked + rematted like rwkv6._wkv_scan: a flat scan's backward
    saves the (B,H,hd,ds) state at every one of S steps; chunking keeps
    only S/256 boundary states and recomputes within chunks."""

    def step(s_, inp):
        xt, bt, ct, dtt = inp                       # (B,H,hd),(B,ds),(B,ds),(B,H)
        decay = jnp.exp(dtt * a)                    # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        s_ = s_ * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", s_.astype(ct.dtype), ct)
        return s_, y

    seq = xh.shape[1]
    xs = (xh.transpose(1, 0, 2, 3), bmat.transpose(1, 0, 2),
          cmat.transpose(1, 0, 2), dt_.transpose(1, 0, 2))
    if seq <= _SSD_CHUNK or seq % _SSD_CHUNK:
        state, ys = jax.lax.scan(step, state, xs)
        return ys.transpose(1, 0, 2, 3), state

    nc = seq // _SSD_CHUNK
    xs_c = tuple(t.reshape((nc, _SSD_CHUNK) + t.shape[1:]) for t in xs)

    def chunk(s_, inp):
        return jax.lax.scan(step, s_, inp)

    chunk = jax.checkpoint(chunk, policy=jax.checkpoint_policies.nothing_saveable)
    state, ys = jax.lax.scan(chunk, state, xs_c)
    ys = ys.reshape((seq,) + ys.shape[2:])
    return ys.transpose(1, 0, 2, 3), state


def _mamba_layer(x, p, cfg: ModelConfig, ssm_state, conv_state):
    b, s, d = x.shape
    di, ds = cfg.inner, cfg.ssm_state
    nh, hd = di // cfg.ssm_head_dim, cfg.ssm_head_dim
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    xbc, z, dt_raw = jnp.split(zxbcdt, [di + 2 * ds, 2 * di + 2 * ds], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xin, bmat, cmat = jnp.split(xbc, [di, di + ds], axis=-1)
    dt_ = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, s, nh, hd)
    # recurrence state stays f32; streams stay in the compute dtype
    y, ssm_state = _ssd_scan(xh, bmat, cmat, dt_, a, ssm_state)
    y = y.astype(x.dtype) + p["D"][None, None, :, None] * xh
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return act_constrain(x + out, cfg.act_shard), ssm_state, conv_state


# ---------------------------------------------------------------------------
# Shared attention block
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig):
    d, hd, h_, kv, f = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "ln_attn": jnp.ones((d,), dt),
        "wq": dense_init(ks[0], (d, h_ * hd), dt),
        "wk": dense_init(ks[1], (d, kv * hd), dt),
        "wv": dense_init(ks[2], (d, kv * hd), dt),
        "wo": dense_init(ks[3], (h_ * hd, d), dt),
        "ln_mlp": jnp.ones((d,), dt),
        "w_gate": dense_init(ks[4], (d, f), dt),
        "w_up": dense_init(ks[5], (d, f), dt),
        "w_down": dense_init(ks[6], (f, d), dt),
    }


def _attn_block(x, p, cfg: ModelConfig, sin, cos):
    b, s, _ = x.shape
    h = rms_norm(x, p["ln_attn"], cfg.rms_eps)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    attn = gqa_attention(q, k, v, causal=True, impl=cfg.attn_impl)
    x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, s, -1), p["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.rms_eps)
    x = x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return act_constrain(x, cfg.act_shard), (k, v)


def _attn_block_decode(x, p, cfg: ModelConfig, sin, cos, k_cache, v_cache, pos):
    b = x.shape[0]
    h = rms_norm(x, p["ln_attn"], cfg.rms_eps)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
    q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, pos, 0, 0))
    attn = gqa_attention_cached(q, k_cache, v_cache, pos + 1)
    x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, 1, -1), p["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.rms_eps)
    x = x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def _n_super(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.shared_attn_every == 0
    return cfg.n_layers // cfg.shared_attn_every


def init(cfg: ModelConfig, key) -> Dict:
    dt = dtype_of(cfg.param_dtype)
    k_emb, k_blocks, k_attn, k_head = jax.random.split(key, 4)
    n_sup, every = _n_super(cfg), cfg.shared_attn_every
    flat = stack_layers(lambda k: _init_mamba(k, cfg), k_blocks, cfg.n_layers)
    blocks = jax.tree.map(
        lambda x: x.reshape((n_sup, every) + x.shape[1:]), flat)
    return {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "blocks": blocks,                       # (n_super, every, ...)
        "shared_attn": _init_attn(k_attn, cfg),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "head": dense_init(k_head, (cfg.d_model, cfg.vocab), dt),
    }


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    dt = dtype_of(cfg.compute_dtype)
    di, ds = cfg.inner, cfg.ssm_state
    nh, hd = di // cfg.ssm_head_dim, cfg.ssm_head_dim
    n_sup, every = _n_super(cfg), cfg.shared_attn_every
    return {
        "ssm": jnp.zeros((n_sup, every, batch_size, nh, hd, ds), jnp.float32),
        "conv": jnp.zeros((n_sup, every, batch_size, cfg.conv_kernel - 1,
                           di + 2 * ds), dt),
        "k": jnp.zeros((n_sup, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((n_sup, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def _trunk(params, h, cfg: ModelConfig, cache, sin, cos):
    def inner(x, inp):
        p, st, cv = inp
        x, st, cv = _mamba_layer(x, p, cfg, st, cv)
        return x, (st, cv)

    def super_block(x, inp):
        p_m, st, cv = inp
        x, (st, cv) = jax.lax.scan(inner, x, (p_m, st, cv),
                                   unroll=cfg.shared_attn_every)
        x, (k, v) = _attn_block(x, params["shared_attn"], cfg, sin, cos)
        return x, (st, cv, k, v)

    body = super_block
    if cfg.remat:
        body = jax.checkpoint(
            super_block, policy=jax.checkpoint_policies.nothing_saveable)
    h, (ssm, conv, ks, vs) = jax.lax.scan(
        body, h, (params["blocks"], cache["ssm"], cache["conv"]),
        unroll=cfg.scan_unroll(_n_super(cfg)))
    return h, ssm, conv, ks, vs


def forward(params, batch, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(dt)
    b, s = batch["tokens"].shape
    sin, cos = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd, cfg.rope_theta)
    cache = init_cache(cfg, b, 0)
    h, *_ = _trunk(params, h, cfg, cache, sin, cos)
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))


def prefill(params, batch, cfg: ModelConfig, cache):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(dt)
    s = batch["tokens"].shape[1]
    sin, cos = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd, cfg.rope_theta)
    h, ssm, conv, ks, vs = _trunk(params, h, cfg, cache, sin, cos)
    cache = dict(cache)
    cache["ssm"], cache["conv"] = ssm, conv
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    h = rms_norm(h[:, -1:], params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype)), cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][tokens].astype(dt)
    pos = cache["pos"]
    sin, cos = rope_tables(pos[None], cfg.hd, cfg.rope_theta)

    def inner(x, inp):
        p, st, cv = inp
        x, st, cv = _mamba_layer(x, p, cfg, st, cv)
        return x, (st, cv)

    def super_block(x, inp):
        p_m, st, cv, kc, vc = inp
        x, (st, cv) = jax.lax.scan(inner, x, (p_m, st, cv),
                                   unroll=cfg.shared_attn_every)
        x, kc, vc = _attn_block_decode(
            x, params["shared_attn"], cfg, sin, cos, kc, vc, pos)
        return x, (st, cv, kc, vc)

    h, (ssm, conv, ks, vs) = jax.lax.scan(
        super_block, h,
        (params["blocks"], cache["ssm"], cache["conv"], cache["k"], cache["v"]),
        unroll=cfg.scan_unroll(_n_super(cfg)))
    cache = {"ssm": ssm, "conv": conv, "k": ks, "v": vs, "pos": pos + 1}
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype)), cache
