"""Dense GQA transformer trunk (llama3 / qwen3 / qwen1.5 / smollm /
phi-3-vision backbone).

Layer-stacked params + ``lax.scan`` over layers; optional
``jax.checkpoint`` remat around the scanned body; (train, prefill,
decode) triple with a functional KV cache.

The VLM variant (phi-3-vision) prepends ``n_patches`` precomputed patch
embeddings (the stubbed CLIP frontend per the assignment) to the token
embeddings; everything downstream is the same trunk.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import act_constrain, constrain
from .config import ModelConfig
from .layers import (apply_rope, dense_init, dtype_of, gqa_attention,
                     gqa_attention_cached, rms_norm, rope_tables,
                     stack_layers, swiglu)

__all__ = ["init", "forward", "init_cache", "prefill", "decode_step"]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig):
    d, hd, h, kv, f = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "ln_attn": jnp.ones((d,), dt),
        "wq": dense_init(ks[0], (d, h * hd), dt),
        "wk": dense_init(ks[1], (d, kv * hd), dt),
        "wv": dense_init(ks[2], (d, kv * hd), dt),
        "wo": dense_init(ks[3], (h * hd, d), dt),
        "ln_mlp": jnp.ones((d,), dt),
        "w_gate": dense_init(ks[4], (d, f), dt),
        "w_up": dense_init(ks[5], (d, f), dt),
        "w_down": dense_init(ks[6], (f, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def init(cfg: ModelConfig, key) -> Dict:
    dt = dtype_of(cfg.param_dtype)
    k_emb, k_layers, k_head, k_vis = jax.random.split(key, 4)
    params = {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "layers": stack_layers(lambda k: _init_layer(k, cfg), k_layers, cfg.n_layers),
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, (cfg.d_model, cfg.vocab), dt)
    if cfg.family == "vlm":
        # stub frontend: a single projection of precomputed patch embeds
        params["vis_proj"] = dense_init(k_vis, (cfg.d_model, cfg.d_model), dt)
    return params


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _qkv(p, cfg: ModelConfig, x):
    b, s, d = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    return q, k, v


def _layer(x, p, cfg: ModelConfig, sin, cos):
    h = rms_norm(x, p["ln_attn"], cfg.rms_eps)
    q, k, v = _qkv(p, cfg, h)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    attn = gqa_attention(q, k, v, causal=True, impl=cfg.attn_impl)
    b, s, _, _ = attn.shape
    x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, s, -1), p["wo"])
    x = act_constrain(x, cfg.act_shard)
    h = rms_norm(x, p["ln_mlp"], cfg.rms_eps)
    x = x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return act_constrain(x, cfg.act_shard), (k, v)


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(dt)
    if cfg.family == "vlm":
        vis = jnp.einsum("bpd,de->bpe", batch["patches"].astype(dt),
                         params["vis_proj"].astype(dt))
        h = jnp.concatenate([vis, h], axis=1)
    return h


def forward(params, batch, cfg: ModelConfig):
    """batch: tokens (B, S) [+ patches (B, Np, d) for vlm] → logits."""
    h = _embed_inputs(params, batch, cfg)
    s_total = h.shape[1]
    pos = jnp.arange(s_total, dtype=jnp.int32)
    sin, cos = rope_tables(pos, cfg.hd, cfg.rope_theta)

    def body(x, p):
        y, _ = _layer(x, p, cfg, sin, cos)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["layers"], unroll=cfg.scan_unroll(cfg.n_layers))
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    if cfg.family == "vlm":
        h = h[:, -batch["tokens"].shape[1]:]
    return _lm_head(params, h)


def _lm_head(params, h):
    """Logits; tied embeddings avoid materializing a transposed copy."""
    if "head" in params:
        return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))
    return jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    dt = dtype_of(cfg.compute_dtype)
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg: ModelConfig, cache):
    """Run the prompt through the trunk, writing the KV cache. Returns
    (logits of the last position, cache)."""
    h = _embed_inputs(params, batch, cfg)
    s = h.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    sin, cos = rope_tables(pos, cfg.hd, cfg.rope_theta)

    def body(x, p):
        y, (k, v) = _layer(x, p, cfg, sin, cos)
        return y, (k, v)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, (ks, vs) = jax.lax.scan(body, h, params["layers"], unroll=cfg.scan_unroll(cfg.n_layers))
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    h = rms_norm(h[:, -1:], params["ln_f"], cfg.rms_eps)
    return _lm_head(params, h), cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    """tokens: (B, 1) — one new token per sequence; attends to
    cache[:pos+1]. Returns (logits (B, 1, V), updated cache)."""
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][tokens].astype(dt)            # (B, 1, d)
    pos = cache["pos"]
    sin, cos = rope_tables(pos[None], cfg.hd, cfg.rope_theta)  # (1, hd/2)

    def body(x, inp):
        p, k_cache, v_cache = inp
        hh = rms_norm(x, p["ln_attn"], cfg.rms_eps)
        q, k, v = _qkv(p, cfg, hh)                    # (B, 1, ·, hd)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        attn = gqa_attention_cached(q, k_cache, v_cache, pos + 1)
        b = attn.shape[0]
        x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, 1, -1), p["wo"])
        hh = rms_norm(x, p["ln_mlp"], cfg.rms_eps)
        x = x + swiglu(hh, p["w_gate"], p["w_up"], p["w_down"])
        return x, (k_cache, v_cache)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]),
                               unroll=cfg.scan_unroll(cfg.n_layers))
    cache = {"k": ks, "v": vs, "pos": pos + 1}
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    return _lm_head(params, h), cache
