"""Architecture configuration shared by every model family."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen1.5
    rope_theta: float = 500_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0               # per-expert FFN hidden dim
    moe_dispatch: str = "gshard"    # gshard | grouped (paper-balanced)
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0                # mamba expansion (default 2*d_model)
    shared_attn_every: int = 0      # zamba2: one shared attn block per N
    conv_kernel: int = 4
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500      # stub frontend output length
    # vlm
    n_patches: int = 0              # stub patch-embedding prefix length
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    attn_impl: str = "xla"          # xla | pallas (flash)
    # layer-loop lowering: scan (HLO size O(1) in depth; XLA cost analysis
    # counts the body once) vs unroll (exact cost analysis — the dry-run
    # flips this on)
    unroll_layers: bool = False
    # partial unroll factor for the layer scan (dry-run cost extrapolation
    # compiles u=1 and u=2 and extrapolates linearly; 94-layer full unroll
    # is not compilable in reasonable time on one CPU core)
    layer_unroll: int = 1
    # layer-boundary activation sharding: none | seq (Megatron-SP style,
    # sequence over the model axis) | d (feature dim over model axis)
    act_shard: str = "none"
    # long-context capability flag (sub-quadratic decode state)
    subquadratic: bool = False

    def scan_unroll(self, length: int) -> int:
        """Unroll factor for a layer scan of ``length`` trips."""
        if self.unroll_layers:
            return length
        return max(1, min(self.layer_unroll, length))

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def inner(self) -> int:
        return self.d_inner or (2 * self.d_model)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline numbers)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            ffn = 3 * d * self.d_ff
            return emb + self.n_layers * (attn + ffn)
        if self.family == "moe":
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            moe = self.num_experts * 3 * d * self.d_expert + d * self.num_experts
            return emb + self.n_layers * (attn + moe)
        if self.family == "ssm":        # rwkv6
            tmix = 4 * d * d + d * d    # r,k,v,g + output
            cmix = 2 * d * self.d_ff if self.d_ff else 7 * d * d
            return emb + self.n_layers * (tmix + cmix)
        if self.family == "hybrid":     # zamba2
            di = self.inner
            mamba = d * (2 * di) + di * d + di * (2 * self.ssm_state)
            attn = 4 * d * d + 3 * d * self.d_ff
            n_attn = (self.n_layers // self.shared_attn_every) if self.shared_attn_every else 0
            return emb + self.n_layers * mamba + attn  # shared: counted once
        if self.family == "audio":
            enc = self.n_encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            dec = self.n_layers * (8 * d * d + 2 * d * self.d_ff)
            return emb + enc + dec
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        attn = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.hd * d
        moe_active = self.top_k * 3 * d * self.d_expert + d * self.num_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (attn + moe_active)
