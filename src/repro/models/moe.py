"""Mixture-of-Experts trunk (qwen3-moe / granite-moe).

Attention is the dense GQA block; the FFN is top-k routed over E experts.
Two dispatch modes:

  * ``gshard`` (default) — capacity-based one-hot dispatch/combine
    einsums. Static shapes, differentiable, EP-shardable (experts over
    the ``model`` mesh axis → GSPMD lowers the dispatch to all_to_all).
    Capacity = ⌈top_k·T/E⌉·capacity_factor per expert; overflow drops
    (standard GShard semantics).

  * ``grouped`` — the paper-technique path: tokens are *sorted by
    expert* (the expert-load bincount is the BDM analog, experts =
    blocks, tokens = entities) and pushed through the Pallas grouped
    GEMM (kernels/grouped_mm.py) with tile-aligned segments. Skew in
    tokens-per-expert becomes tile-count skew, which the kernel absorbs
    without capacity drops — the MoE incarnation of BlockSplit's "split
    large blocks into fixed-size work units". Used on the serving path
    and in tests; training keeps gshard for differentiability.

Auxiliary load-balancing loss (Switch-style): E · Σ_e f_e · p_e, where
f_e is the token fraction and p_e the mean router prob of expert e.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding import act_constrain, constrain
from .config import ModelConfig
from .layers import (apply_rope, dense_init, dtype_of, gqa_attention,
                     gqa_attention_cached, rms_norm, rope_tables,
                     stack_layers)
from . import transformer as _tf

__all__ = ["init", "forward", "init_cache", "prefill", "decode_step",
           "moe_ffn", "router_aux_loss"]


def _init_layer(key, cfg: ModelConfig):
    d, hd, h, kv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    e, fe = cfg.num_experts, cfg.d_expert
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    p = {
        "ln_attn": jnp.ones((d,), dt),
        "wq": dense_init(ks[0], (d, h * hd), dt),
        "wk": dense_init(ks[1], (d, kv * hd), dt),
        "wv": dense_init(ks[2], (d, kv * hd), dt),
        "wo": dense_init(ks[3], (h * hd, d), dt),
        "ln_mlp": jnp.ones((d,), dt),
        "router": dense_init(ks[4], (d, e), dt),
        "experts": {
            "w_gate": dense_init(ks[5], (e, d, fe), dt),
            "w_up": dense_init(ks[6], (e, d, fe), dt),
            "w_down": dense_init(ks[7], (e, fe, d), dt),
        },
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def init(cfg: ModelConfig, key) -> Dict:
    dt = dtype_of(cfg.param_dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    return {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "layers": stack_layers(lambda k: _init_layer(k, cfg), k_layers, cfg.n_layers),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "head": dense_init(k_head, (cfg.d_model, cfg.vocab), dt),
    }


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------

def _route(p, x, cfg: ModelConfig):
    """x: (T, d) → (weights (T, k), expert_ids (T, k), probs (T, E))."""
    logits = jnp.einsum("td,de->te", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)   # renormalize
    return w.astype(x.dtype), ids, probs


_MOE_GROUP_TOKENS = 4096  # target tokens per dispatch group


def _experts_gshard(p, x, w, ids, cfg: ModelConfig):
    """Grouped capacity dispatch via batched sort+gather (GShard
    semantics, honest FLOPs, shardable). x: (T, d) -> (T, d).

    Two classic pitfalls are avoided:
      * one-hot dispatch einsums cost T*E*C*d FLOPs (~280x the useful
        expert GEMM at the 1M-token train cell) -- dispatch indices come
        from a per-group sort and the data moves through pure gathers
        (O(T*k*d), zero matmul FLOPs);
      * a single global scatter does not SPMD-partition (GSPMD
        replicates it -> hundreds of GiB per device) -- so tokens are
        reshaped into G groups of ~4k tokens, every dispatch op is
        *batched over G*, and G shards over the data axes. The expert
        buffer (G, E, C, d) is then constrained to E-over-``model`` --
        GSPMD lowers that reshard to the EP all_to_all.

    Capacity C = ceil(cf*k*Tg/E) per group; overflow drops first-come-
    first-served within the group exactly as in GShard (groups = the
    paper's input partitions, one more place its per-partition
    decomposition shows up).
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    g_count = max(1, t // _MOE_GROUP_TOKENS)
    while t % g_count:
        g_count -= 1
    tg = t // g_count
    cap = max(1, -(-int(cfg.capacity_factor * k * tg) // e))
    tk = tg * k

    # groups shard over EVERY mesh axis (dp AND model) — the dispatch-side
    # buffers scale 1/256, and the dp→EP reshard below stays an all_to_all
    xg = x.reshape(g_count, tg, d)
    xg = constrain(xg, ("pod", "data", "model"), None, None)
    ids_g = ids.reshape(g_count, tg, k)
    w_g = w.reshape(g_count, tg, k)

    flat_ids = ids_g.reshape(g_count, tk)
    order = jnp.argsort(flat_ids, axis=1, stable=True)        # (G, Tk)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=1)
    counts = jax.vmap(lambda i: jnp.bincount(i, length=e))(flat_ids)  # (G, E)
    start = jnp.concatenate(
        [jnp.zeros((g_count, 1), counts.dtype),
         jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)          # (G, E)
    pos = (jnp.arange(tk, dtype=jnp.int32)[None]
           - jnp.take_along_axis(start, sorted_ids, axis=1).astype(jnp.int32))
    keep_sorted = pos < cap                                    # (G, Tk)

    # dispatch gather: slot (e, c) <- sorted position start[e] + c
    gall = ("pod", "data", "model")
    slot_src = start[:, :, None] + jnp.arange(cap, dtype=start.dtype)  # (G,E,C)
    slot_valid = (jnp.arange(cap)[None, None, :]
                  < jnp.minimum(counts, cap)[:, :, None])
    slot_src = jnp.minimum(slot_src, tk - 1).reshape(g_count, e * cap)
    src_token = jnp.take_along_axis(
        order, slot_src.astype(order.dtype), axis=1) // k
    src_token = constrain(src_token, gall, None)
    xe = jnp.take_along_axis(xg, src_token[..., None], axis=1)  # (G, E*C, d)
    xe = xe * slot_valid.reshape(g_count, e * cap, 1).astype(xe.dtype)
    xe = constrain(xe, gall, None, None)
    xe = xe.reshape(g_count, e, cap, d)
    xe = constrain(xe, ("pod", "data"), "model", None, None)    # EP reshard
    gt = jnp.einsum("gecd,edf->gecf", xe, p["experts"]["w_gate"])
    ut = jnp.einsum("gecd,edf->gecf", xe, p["experts"]["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gt) * ut,
                    p["experts"]["w_down"])                     # (G, E, C, d)
    ye = constrain(ye, ("pod", "data"), "model", None, None)

    # combine: loop the k slots (static) — gathers stay (G, Tg, d) so the
    # peak never holds the (G, T·k, d) replicated buffer, and every
    # G-batched tensor is pinned to G-over-all-axes
    ye_flat = constrain(ye.reshape(g_count, e * cap, d), gall, None, None)
    row = sorted_ids * cap + jnp.minimum(pos, cap - 1)          # (G, Tk)
    row = jnp.where(keep_sorted, row, e * cap - 1)
    inv = jnp.argsort(order, axis=1)                            # slot -> sorted pos
    w_flat = w_g.reshape(g_count, tk)
    y = jnp.zeros((g_count, tg, d), x.dtype)
    y = constrain(y, gall, None, None)
    for j in range(k):
        sorted_pos = inv[:, j::k]                               # (G, Tg)
        rows_j = jnp.take_along_axis(row, sorted_pos, axis=1)
        keep_j = jnp.take_along_axis(keep_sorted, sorted_pos, axis=1)
        y_j = jnp.take_along_axis(ye_flat, rows_j[..., None].astype(jnp.int32),
                                  axis=1)                        # (G, Tg, d)
        y_j = constrain(y_j, gall, None, None)
        scale = jnp.where(keep_j, w_flat[:, j::k], 0.0)
        y = y + y_j * scale[..., None].astype(y_j.dtype)
    return y.reshape(t, d).astype(x.dtype)


def _experts_grouped(p, x, w, ids, cfg: ModelConfig, impl: str = "pallas"):
    """Sort-by-expert + Pallas grouped GEMM (tile-aligned, drop-free)."""
    from ..kernels import ops

    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    tk = t * k
    bt = 128 if tk >= 128 * e else 8   # small-batch decode: narrow tiles
    flat_ids = ids.reshape(tk)
    flat_w = w.reshape(tk)
    order = jnp.argsort(flat_ids, stable=True)
    x_rep = x[order // k]                                       # (T·k, d)
    # tile-aligned segments: worst case every expert pads one tile
    counts = jnp.bincount(flat_ids, length=e)
    tp = (-(-tk // bt) + e) * bt  # static upper bound on padded length
    padded = -(-counts // bt) * bt
    pstart = jnp.concatenate([jnp.zeros(1, padded.dtype), jnp.cumsum(padded)[:-1]])
    start = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    # destination row of each sorted token
    seg_of = jnp.searchsorted(jnp.cumsum(counts), jnp.arange(tk), side="right")
    dst = pstart[seg_of] + (jnp.arange(tk) - start[seg_of])
    xg = jnp.zeros((tp, d), x.dtype).at[dst].set(x_rep)
    tile_expert = jnp.minimum(jnp.searchsorted(
        jnp.cumsum(padded), jnp.arange(tp // bt) * bt, side="right"
    ), e - 1).astype(jnp.int32)  # clamp tail tiles past the real rows
    g = ops.grouped_matmul(xg, tile_expert, p["experts"]["w_gate"],
                           block_t=bt, impl=impl)
    u = ops.grouped_matmul(xg, tile_expert, p["experts"]["w_up"],
                           block_t=bt, impl=impl)
    yg = ops.grouped_matmul((jax.nn.silu(g) * u).astype(x.dtype), tile_expert,
                            p["experts"]["w_down"], block_t=bt, impl=impl)
    # yg[dst[i]] is the output of *sorted* slot i = original slot order[i]
    y_rep = yg[dst] * flat_w[order][:, None]                    # (T·k, d)
    out = jnp.zeros((t, d), x.dtype).at[order // k].add(y_rep)
    return out


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, d) → (y, aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    w, ids, probs = _route(p, xt, cfg)
    if cfg.moe_dispatch == "grouped":
        y = _experts_grouped(p, xt, w, ids, cfg)
    else:
        y = _experts_gshard(p, xt, w, ids, cfg)
    aux = router_aux_loss(ids, probs, cfg)
    return y.reshape(b, s, d), aux


def router_aux_loss(ids, probs, cfg: ModelConfig):
    e = cfg.num_experts
    f = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    pbar = probs.mean(axis=0)
    return e * jnp.sum(f * pbar)


# ---------------------------------------------------------------------------
# Trunk
# ---------------------------------------------------------------------------

def _layer(x, p, cfg: ModelConfig, sin, cos):
    h = rms_norm(x, p["ln_attn"], cfg.rms_eps)
    q, k, v = _tf._qkv(p, cfg, h)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    attn = gqa_attention(q, k, v, causal=True, impl=cfg.attn_impl)
    b, s, _, _ = attn.shape
    x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, s, -1), p["wo"])
    x = act_constrain(x, cfg.act_shard)
    h = rms_norm(x, p["ln_mlp"], cfg.rms_eps)
    y, aux = moe_ffn(p, h, cfg)
    return act_constrain(x + y, cfg.act_shard), aux, (k, v)


def forward(params, batch, cfg: ModelConfig, return_aux: bool = False):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(dt)
    s = h.shape[1]
    sin, cos = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd, cfg.rope_theta)

    def body(carry, p):
        x, aux_sum = carry
        y, aux, _ = _layer(x, p, cfg, sin, cos)
        return (y, aux_sum + aux), None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                               params["layers"],
                               unroll=cfg.scan_unroll(cfg.n_layers))
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))
    if return_aux:
        return logits, aux / cfg.n_layers
    return logits


init_cache = _tf.init_cache


def prefill(params, batch, cfg: ModelConfig, cache):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(dt)
    s = h.shape[1]
    sin, cos = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd, cfg.rope_theta)

    def body(carry, p):
        y, _, (k, v) = _layer(carry, p, cfg, sin, cos)
        return y, (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, params["layers"], unroll=cfg.scan_unroll(cfg.n_layers))
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    h = rms_norm(h[:, -1:], params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype)), cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    h = params["embed"][tokens].astype(dt)
    pos = cache["pos"]
    sin, cos = rope_tables(pos[None], cfg.hd, cfg.rope_theta)

    def body(x, inp):
        p, k_cache, v_cache = inp
        hh = rms_norm(x, p["ln_attn"], cfg.rms_eps)
        q, k, v = _tf._qkv(p, cfg, hh)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        attn = gqa_attention_cached(q, k_cache, v_cache, pos + 1)
        b = attn.shape[0]
        x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, 1, -1), p["wo"])
        hh = rms_norm(x, p["ln_mlp"], cfg.rms_eps)
        y, _ = moe_ffn(p, hh, cfg)
        return x + y, (k_cache, v_cache)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]),
                               unroll=cfg.scan_unroll(cfg.n_layers))
    cache = {"k": ks, "v": vs, "pos": pos + 1}
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype)), cache
