"""Whisper-base (encoder-decoder, arXiv:2212.04356) — transformer backbone
only; the log-mel conv frontend is a STUB per the assignment:
``input_specs()`` feeds precomputed (B, n_frames, d_model) frame
embeddings.

Encoder: bidirectional pre-LN MHA + GELU MLP over frames (sinusoidal
positions). Decoder: causal self-attn + cross-attn to encoder states +
GELU MLP; logits through the tied token embedding. Positions are
sinusoidal on both sides (Whisper's decoder uses a learned table; we
swap it for sinusoids so the 32k serving shapes need no 32k-row learned
table — recorded in DESIGN.md §Assumptions).

Decode cache: self-attn K/V (L, B, Smax, H, hd) + cross K/V precomputed
once from the encoder output at prefill.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..sharding import act_constrain, constrain
from .config import ModelConfig
from .layers import (dense_init, dtype_of, gqa_attention,
                     gqa_attention_cached, layer_norm, stack_layers)

__all__ = ["init", "forward", "init_cache", "prefill", "decode_step",
           "encode"]


def _sinusoid(positions, d: int):
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions.astype(jnp.float32)[:, None] * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_attn(key, cfg, cross: bool = False):
    d, hd, h_ = cfg.d_model, cfg.hd, cfg.n_heads
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h_ * hd), dt),
        "wk": dense_init(ks[1], (d, h_ * hd), dt),
        "wv": dense_init(ks[2], (d, h_ * hd), dt),
        "wo": dense_init(ks[3], (h_ * hd, d), dt),
        "bq": jnp.zeros((h_ * hd,), dt),
        "bv": jnp.zeros((h_ * hd,), dt),
        "bo": jnp.zeros((d,), dt),
    }


def _init_enc_layer(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "ln1_w": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "attn": _init_attn(ks[0], cfg),
        "ln2_w": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        "fc1": dense_init(ks[1], (d, f), dt), "fc1_b": jnp.zeros((f,), dt),
        "fc2": dense_init(ks[2], (f, d), dt), "fc2_b": jnp.zeros((d,), dt),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = _init_enc_layer(ks[0], cfg)
    p.update({
        "ln_x_w": jnp.ones((d,), dt), "ln_x_b": jnp.zeros((d,), dt),
        "xattn": _init_attn(ks[1], cfg, cross=True),
    })
    return p


def init(cfg: ModelConfig, key) -> Dict:
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "enc_layers": stack_layers(lambda k: _init_enc_layer(k, cfg), ks[1],
                                   cfg.n_encoder_layers),
        "enc_ln_w": jnp.ones((cfg.d_model,), dt),
        "enc_ln_b": jnp.zeros((cfg.d_model,), dt),
        "dec_layers": stack_layers(lambda k: _init_dec_layer(k, cfg), ks[2],
                                   cfg.n_layers),
        "dec_ln_w": jnp.ones((cfg.d_model,), dt),
        "dec_ln_b": jnp.zeros((cfg.d_model,), dt),
    }


def _mha(p, xq, xkv, cfg: ModelConfig, causal: bool):
    b, s, _ = xq.shape
    h_, hd = cfg.n_heads, cfg.hd
    q = (jnp.einsum("bsd,dh->bsh", xq, p["wq"]) + p["bq"]).reshape(b, s, h_, hd)
    k = jnp.einsum("bsd,dh->bsh", xkv, p["wk"]).reshape(b, -1, h_, hd)
    v = (jnp.einsum("bsd,dh->bsh", xkv, p["wv"]) + p["bv"]).reshape(b, -1, h_, hd)
    o = gqa_attention(q, k, v, causal=causal, impl=cfg.attn_impl)
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), p["wo"]) + p["bo"]


def _mlp(p, x, cfg: ModelConfig):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["fc1"]) + p["fc1_b"])
    return jnp.einsum("bsf,fd->bsd", h, p["fc2"]) + p["fc2_b"]


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, d) stub embeddings → encoder states (B, F, d)."""
    dt = dtype_of(cfg.compute_dtype)
    f_len = frames.shape[1]
    h = frames.astype(dt) + _sinusoid(jnp.arange(f_len), cfg.d_model).astype(dt)

    def body(x, p):
        a = layer_norm(x, p["ln1_w"], p["ln1_b"])
        x = x + _mha(p["attn"], a, a, cfg, causal=False)
        m = layer_norm(x, p["ln2_w"], p["ln2_b"])
        x = x + _mlp(p, m, cfg)
        return act_constrain(x, cfg.act_shard), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"],
                        unroll=cfg.scan_unroll(cfg.n_encoder_layers))
    return layer_norm(h, params["enc_ln_w"], params["enc_ln_b"])


def _decoder(params, tokens, enc, cfg: ModelConfig, pos0: int = 0):
    dt = dtype_of(cfg.compute_dtype)
    b, s = tokens.shape
    pos = jnp.arange(s, dtype=jnp.int32) + pos0
    h = params["embed"][tokens].astype(dt) + _sinusoid(pos, cfg.d_model).astype(dt)

    def body(x, p):
        a = layer_norm(x, p["ln1_w"], p["ln1_b"])
        sa = _mha(p["attn"], a, a, cfg, causal=True)
        x = x + sa
        cx = layer_norm(x, p["ln_x_w"], p["ln_x_b"])
        x = x + _mha(p["xattn"], cx, enc, cfg, causal=False)
        m = layer_norm(x, p["ln2_w"], p["ln2_b"])
        x = x + _mlp(p, m, cfg)
        return act_constrain(x, cfg.act_shard), None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body_fn, h, params["dec_layers"], unroll=cfg.scan_unroll(cfg.n_layers))
    h = layer_norm(h, params["dec_ln_w"], params["dec_ln_b"])
    return jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))


def forward(params, batch, cfg: ModelConfig):
    """batch: frames (B, F, d) + tokens (B, S) → decoder logits."""
    enc = encode(params, batch["frames"], cfg)
    return _decoder(params, batch["tokens"], enc, cfg)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    dt = dtype_of(cfg.compute_dtype)
    L, h_, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch_size, max_len, h_, hd), dt),
        "v": jnp.zeros((L, batch_size, max_len, h_, hd), dt),
        "xk": jnp.zeros((L, batch_size, cfg.n_audio_frames, h_, hd), dt),
        "xv": jnp.zeros((L, batch_size, cfg.n_audio_frames, h_, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg: ModelConfig, cache):
    """Encode frames, precompute cross K/V, run the prompt through the
    decoder writing the self-attn cache."""
    dt = dtype_of(cfg.compute_dtype)
    enc = encode(params, batch["frames"], cfg)
    b, s = batch["tokens"].shape
    h_, hd = cfg.n_heads, cfg.hd
    pos = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"][batch["tokens"]].astype(dt) \
        + _sinusoid(pos, cfg.d_model).astype(dt)

    def body(x, p):
        a = layer_norm(x, p["ln1_w"], p["ln1_b"])
        q = (jnp.einsum("bsd,dh->bsh", a, p["attn"]["wq"]) + p["attn"]["bq"]
             ).reshape(b, s, h_, hd)
        k = jnp.einsum("bsd,dh->bsh", a, p["attn"]["wk"]).reshape(b, s, h_, hd)
        v = (jnp.einsum("bsd,dh->bsh", a, p["attn"]["wv"]) + p["attn"]["bv"]
             ).reshape(b, s, h_, hd)
        o = gqa_attention(q, k, v, causal=True, impl=cfg.attn_impl)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1),
                           p["attn"]["wo"]) + p["attn"]["bo"]
        cx = layer_norm(x, p["ln_x_w"], p["ln_x_b"])
        xk = jnp.einsum("bfd,dh->bfh", enc, p["xattn"]["wk"]
                        ).reshape(b, -1, h_, hd)
        xv = (jnp.einsum("bfd,dh->bfh", enc, p["xattn"]["wv"]) + p["xattn"]["bv"]
              ).reshape(b, -1, h_, hd)
        qx = (jnp.einsum("bsd,dh->bsh", cx, p["xattn"]["wq"]) + p["xattn"]["bq"]
              ).reshape(b, s, h_, hd)
        ox = gqa_attention(qx, xk, xv, causal=False, impl=cfg.attn_impl)
        x = x + jnp.einsum("bsh,hd->bsd", ox.reshape(b, s, -1),
                           p["xattn"]["wo"]) + p["xattn"]["bo"]
        m = layer_norm(x, p["ln2_w"], p["ln2_b"])
        x = x + _mlp(p, m, cfg)
        return x, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll(cfg.n_layers))
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["xk"], cache["xv"] = xks, xvs
    cache["pos"] = jnp.asarray(s, jnp.int32)
    x = layer_norm(x[:, -1:], params["dec_ln_w"], params["dec_ln_b"])
    return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype)), cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    b = tokens.shape[0]
    h_, hd = cfg.n_heads, cfg.hd
    pos = cache["pos"]
    x = params["embed"][tokens].astype(dt) \
        + _sinusoid(pos[None], cfg.d_model).astype(dt)

    def body(x, inp):
        p, kc, vc, xk, xv = inp
        a = layer_norm(x, p["ln1_w"], p["ln1_b"])
        q = (jnp.einsum("bsd,dh->bsh", a, p["attn"]["wq"]) + p["attn"]["bq"]
             ).reshape(b, 1, h_, hd)
        k = jnp.einsum("bsd,dh->bsh", a, p["attn"]["wk"]).reshape(b, 1, h_, hd)
        v = (jnp.einsum("bsd,dh->bsh", a, p["attn"]["wv"]) + p["attn"]["bv"]
             ).reshape(b, 1, h_, hd)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
        o = gqa_attention_cached(q, kc, vc, pos + 1)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1),
                           p["attn"]["wo"]) + p["attn"]["bo"]
        cx = layer_norm(x, p["ln_x_w"], p["ln_x_b"])
        qx = (jnp.einsum("bsd,dh->bsh", cx, p["xattn"]["wq"]) + p["xattn"]["bq"]
              ).reshape(b, 1, h_, hd)
        ox = gqa_attention_cached(qx, xk, xv, xk.shape[1])
        x = x + jnp.einsum("bsh,hd->bsd", ox.reshape(b, 1, -1),
                           p["xattn"]["wo"]) + p["xattn"]["bo"]
        m = layer_norm(x, p["ln2_w"], p["ln2_b"])
        x = x + _mlp(p, m, cfg)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), unroll=cfg.scan_unroll(cfg.n_layers))
    cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    x = layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
    return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype)), cache
