"""Model zoo: the 10 assigned architectures behind one functional API.

Every family module exposes
    init(cfg, key)                      -> params (nested dict pytree)
    forward(params, batch, cfg)         -> logits          (training)
    init_cache(cfg, batch, seq)         -> cache pytree    (serving)
    prefill(params, tokens, cfg, cache) -> (logits, cache)
    decode_step(params, toks, pos, cache, cfg) -> (logits, cache)

Params are plain nested dicts of jnp arrays with layer-stacked leaves
(leading dim = n_layers) so the trunk is a single ``lax.scan`` — HLO size
stays independent of depth (the 94-layer MoE compiles as fast as the
6-layer Whisper).
"""
try:  # registry imports all families; keep import-light during bring-up
    from .registry import MODEL_FAMILIES, get_model  # noqa: F401
except ImportError:  # pragma: no cover
    pass
