"""Shared functional building blocks (no framework, plain pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dtype_of", "dense_init", "rms_norm", "layer_norm", "rope_tables",
    "apply_rope", "gqa_attention", "gqa_attention_cached", "swiglu",
    "stack_layers",
]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def rope_tables(positions, head_dim: int, theta: float):
    """positions: (...,) int32 → (sin, cos) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (..., S, H, D); sin/cos: (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :] if x.ndim == sin.ndim + 2 else sin
    c = cos[..., None, :] if x.ndim == cos.ndim + 2 else cos
    # interleave-free (rotate-half) convention
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


_ATTN_CHUNK = 1024  # q-chunk for the scanned XLA path (bounds transients)


def gqa_attention(q, k, v, *, causal: bool = True, impl: str = "xla",
                  bias=None):
    """q: (B, S, H, D); k/v: (B, S, KV, D). Returns (B, S, H, D).

    The XLA path scans over query chunks so the (B, H, S, S) logits
    tensor never materializes — peak transient is (B, H, cq, S). This is
    the flash-attention *memory* property without the kernel; the Pallas
    kernel (impl="pallas") additionally gets the compute tiling right on
    real TPUs.
    """
    from ..kernels import ops

    b, s, h, d = q.shape
    kv = k.shape[2]
    if impl in ("pallas", "interpret") and bias is None:
        out = ops.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, impl=impl)
        return out.transpose(0, 2, 1, 3)
    group = h // kv

    # g-major flat head layout: flat head h = g·KV + k. Under random init
    # this is a free reparameterization (loading external checkpoints
    # would permute wq/wo); it makes the *group* dim contiguous so TP can
    # shard it when kv < tp (e.g. qwen3-moe 64h/4kv on a 16-way model
    # axis) — see sharding.attn_logits_constrain.
    def chunk_attn(q_chunk, q_off):
        from ..sharding import attn_logits_constrain

        cq = q_chunk.shape[1]
        qg = q_chunk.reshape(b, cq, group, kv, d)
        # dot in the activation dtype; upcast the logits (see
        # gqa_attention_cached for why not preferred_element_type=f32)
        logits = jnp.einsum("bqgkd,bskd->bgkqs", qg, k
                            ).astype(jnp.float32) * (d ** -0.5)
        logits = attn_logits_constrain(logits)
        if bias is not None:
            logits = logits + jax.lax.dynamic_slice_in_dim(
                bias, q_off, cq, axis=-2) if bias.ndim >= 2 else logits + bias
        if causal:
            rows = q_off + jnp.arange(cq)[:, None]
            cols = jnp.arange(s)[None, :]
            logits = jnp.where((rows >= cols)[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgkqs,bskd->bqgkd", p.astype(v.dtype), v)
        return out.reshape(b, cq, h, d)

    if s <= _ATTN_CHUNK or s % _ATTN_CHUNK != 0:
        return chunk_attn(q, 0)

    nc = s // _ATTN_CHUNK
    qc = q.reshape(b, nc, _ATTN_CHUNK, h, d)

    def body(_, i):
        return None, chunk_attn(qc[:, i], i * _ATTN_CHUNK)

    # remat the chunk: without it the scan's backward saves each chunk's
    # logits/softmax — the full S×S matrix in f32, exactly what chunking
    # was avoiding (flash-backward recompute, in XLA form)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(body, None, jnp.arange(nc))   # (nc, B, cq, H, D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def gqa_attention_cached(q, k_cache, v_cache, cur_len):
    """Single-position decode: q (B, 1, H, D) against a (B, Smax, KV, D)
    cache; positions ≥ cur_len are masked. Returns (B, 1, H, D).

    The QKᵀ dot runs in the cache dtype (bf16 in production): on TPU the
    MXU accumulates f32 natively, while asking XLA:CPU for an f32 dot
    output hoists an f32 *convert of the whole cache* out of the layer
    loop (2.5× cache memory) — so the upcast happens on the (tiny)
    logits instead."""
    b, _, h, d = q.shape
    kv = k_cache.shape[2]
    group = h // kv
    qg = q.reshape(b, group, kv, d)   # g-major, matching gqa_attention
    logits = jnp.einsum("bgkd,bskd->bgks", qg, k_cache
                        ).astype(jnp.float32) * (d ** -0.5)
    pos = jnp.arange(k_cache.shape[1])
    logits = jnp.where(pos[None, None, None] < cur_len, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgks,bskd->bgkd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def stack_layers(init_one, key, n_layers: int):
    """Stack per-layer param trees along a new leading axis (scan layout)."""
    keys = jax.random.split(key, n_layers)
    trees = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
