"""Entity featurization.

Two encodings per entity title:

  * ``encode_titles`` — fixed-length uint8 char codes (+ lengths), the
    input to the exact edit-distance verifier (the paper's matcher).
  * ``ngram_features`` — L2-normalized hashed character-n-gram count
    vectors. Cosine similarity over these is a pure matmul, i.e. MXU
    work — the production filter stage in front of the verifier
    (DESIGN.md §2 "Edit distance on MXU").

Hashing is FNV-1a over the n-gram bytes — deterministic across runs and
processes (no PYTHONHASHSEED dependence), vectorized in numpy.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["encode_titles", "ngram_features"]

_FNV_OFFSET = np.uint64(14695981039346656037)
_FNV_PRIME = np.uint64(1099511628211)


def encode_titles(titles: Sequence[str], max_len: int = 64) -> Tuple[np.ndarray, np.ndarray]:
    """(n, max_len) uint8 char codes (0-padded) and (n,) int32 lengths."""
    n = len(titles)
    out = np.zeros((n, max_len), np.uint8)
    lens = np.zeros(n, np.int32)
    for i, t in enumerate(titles):
        raw = t.encode("utf-8", errors="replace")[:max_len]
        out[i, : len(raw)] = np.frombuffer(raw, np.uint8)
        lens[i] = len(raw)
    return out, lens


def _fnv1a_rows(mat: np.ndarray) -> np.ndarray:
    """Row-wise FNV-1a over a (rows, n) uint8 matrix -> (rows,) uint64."""
    with np.errstate(over="ignore"):
        h = np.full(mat.shape[0], _FNV_OFFSET, np.uint64)
        for c in range(mat.shape[1]):
            h = (h ^ mat[:, c].astype(np.uint64)) * _FNV_PRIME
    return h


def ngram_features(
    titles: Sequence[str] | np.ndarray,
    dim: int = 256,
    n: int = 3,
    max_len: int = 64,
    lengths: np.ndarray | None = None,
    dtype=np.float32,
) -> np.ndarray:
    """Hashed char n-gram count features, L2-normalized. (num, dim).

    Accepts raw strings or a pre-encoded (num, max_len) uint8 matrix (with
    ``lengths``). Titles shorter than ``n`` fall back to a single hash of
    the whole (padded) title so no row is all-zero.
    """
    if isinstance(titles, np.ndarray):
        codes, lens = titles, np.asarray(lengths, np.int64)
    else:
        codes, lens = encode_titles(titles, max_len=max_len)
        lens = lens.astype(np.int64)
    num, L = codes.shape
    feats = np.zeros((num, dim), dtype)
    if L >= n:
        # All n-gram windows as a (num, L-n+1, n) strided view.
        windows = np.lib.stride_tricks.sliding_window_view(codes, n, axis=1)
        ngrams = windows.reshape(num * windows.shape[1], n)
        buckets = (_fnv1a_rows(ngrams) % np.uint64(dim)).astype(np.int64)
        buckets = buckets.reshape(num, windows.shape[1])
        # Window w is valid iff w + n <= len(title).
        valid = (np.arange(windows.shape[1])[None, :] + n) <= lens[:, None]
        rows = np.repeat(np.arange(num), windows.shape[1])
        np.add.at(feats, (rows[valid.ravel()], buckets.ravel()[valid.ravel()]), 1.0)
    short = lens < n
    if short.any():
        h = (_fnv1a_rows(codes[short]) % np.uint64(dim)).astype(np.int64)
        feats[np.flatnonzero(short), h] += 1.0
    norms = np.linalg.norm(feats, axis=1, keepdims=True)
    return (feats / np.maximum(norms, 1e-12)).astype(dtype)
