"""Distributed ER runtime: the paper's two MR jobs as shard_map programs.

Mapping (DESIGN.md §2): input partition Π_i ↔ per-device row shard on the
``data`` (× ``pod``) mesh axis; the shuffle ↔ ``all_gather`` on ICI; a
reduce task ↔ a work shard executed by one device. The number of logical
reduce tasks ``r`` stays decoupled from the device count ``n_dev`` exactly
as in the paper (r = 10·n there).

Job 1 (:func:`compute_bdm_sharded`): each device bincounts its local
blocking keys — its BDM *column* — then one ``all_gather`` produces the
full b × m matrix, replicated. This is Alg. 3 with the footnote-2 combiner
(the local bincount) built in.

Job 2 runs through the unified compiler (``er/compiler``): any plan
lowers to a tile catalog, the cost-LPT scheduler places tiles on
reducers and devices, and ``compiler.execute`` scores every shard
through the fused kernel. The entry points here — ``match_catalog_dist``
(self-join), ``match_catalog_2src_dist`` (query-vs-corpus) and
``match_sn_dist`` (RepSN halo exchange) — are thin shims over that one
executor, kept for their historical signatures. Two genuinely different
legacy executors remain for comparison benchmarks:

  * :func:`match_pair_range_dist` — PairRange fully in-jit: every device
    derives its own pair list from the tiny replicated plan arrays via
    the closed-form inverse — the paper's map-side "relevant ranges"
    computation. No host-side pair materialization.
  * :func:`match_shards_hostplan` — per-device padded row-index arrays,
    O(P) host memory. The before-side of the catalog benchmarks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core.pair_range import PairRangePlan, pairs_of_range_jnp
from ..core.sorted_neighborhood import _w_eff
from .compiler import (DeviceKilledError, FaultEvent, FaultInjector,
                       FaultScript, MatchJob, NoHealthyDevicesError,
                       RecoveryFailedError, SupervisedReport, TileCatalog,
                       TransientScorerError, device_assignment, execute,
                       execute_supervised, lower, make_scorer, pad_tiles,
                       shard_sane, tiles_for_devices)
from .compiler.comms import halo_bytes_per_device
from .compiler.execute import _score_and_compact, _smap
from .compiler.ir import make_job, task_row
from .similarity import two_stage_match

__all__ = [
    "compute_bdm_sharded",
    # fault-tolerant runtime (shim passthrough over er/compiler)
    "DeviceKilledError",
    "FaultEvent",
    "FaultInjector",
    "FaultScript",
    "NoHealthyDevicesError",
    "RecoveryFailedError",
    "SupervisedReport",
    "TransientScorerError",
    "execute_supervised",
    "shard_sane",
    "match_catalog_dist",
    "match_catalog_2src_dist",
    "make_catalog_2src_scorer",
    "score_tiles_2src",
    "match_pair_range_dist",
    "match_sn_dist",
    "match_shards_hostplan",
    "device_assignment",
    "plan_rows_for_devices",
    "plan_tiles_for_devices",
    "pad_device_tiles",
    "sn_replication_volume",
]


# ---------------------------------------------------------------------------
# Job 1: BDM
# ---------------------------------------------------------------------------

def compute_bdm_sharded(block_ids, num_blocks: int, mesh: Mesh,
                        axis: str = "data"):
    """block_ids: (n,) int32 sharded over ``axis``; one device shard = one
    input partition Π_i. Returns the replicated (b, m) BDM, m = axis size."""

    def job1(local_ids):
        col = jnp.bincount(local_ids.reshape(-1), length=num_blocks)
        cols = jax.lax.all_gather(col, axis)          # (m, b)
        return cols.T.astype(jnp.int32)               # (b, m)

    shard = _smap(job1, mesh, in_specs=P(axis), out_specs=P())
    return shard(block_ids)


# ---------------------------------------------------------------------------
# Tile routing shims (scheduling lives in compiler/schedule.py)
# ---------------------------------------------------------------------------

def plan_tiles_for_devices(catalog: TileCatalog, n_dev: int,
                           healthy: Optional[np.ndarray] = None,
                           schedule=None) -> np.ndarray:
    """Partition a tile catalog over devices — see
    :func:`compiler.tiles_for_devices`. Without a schedule, reducers
    route round-robin via :func:`device_assignment` (the baseline)."""
    return tiles_for_devices(catalog, n_dev, healthy, schedule)


def pad_device_tiles(tiles_dev: np.ndarray, chunk: int) -> np.ndarray:
    """Pad the per-device tile cap UP to a multiple of ``chunk`` (>= one
    full chunk) with all-zero entries — the fixed-shape contract the
    resident service's recompile guard depends on
    (:func:`compiler.pad_tiles`)."""
    return pad_tiles(tiles_dev, chunk)


def plan_rows_for_devices(reducer_rows, r: int, n_dev: int,
                          healthy: Optional[np.ndarray] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Concatenate per-reducer (rows_a, rows_b) into per-device padded
    arrays (n_dev, cap). Returns (rows_a, rows_b, valid). Feeds the
    legacy O(P) :func:`match_shards_hostplan` executor only."""
    dev_of = device_assignment(r, n_dev, healthy)
    per_dev_a = [[] for _ in range(n_dev)]
    per_dev_b = [[] for _ in range(n_dev)]
    for k in range(r):
        ra, rb = reducer_rows[k]
        d = int(dev_of[k])
        per_dev_a[d].append(np.asarray(ra, np.int32))
        per_dev_b[d].append(np.asarray(rb, np.int32))
    cat_a = [np.concatenate(x) if x else np.zeros(0, np.int32) for x in per_dev_a]
    cat_b = [np.concatenate(x) if x else np.zeros(0, np.int32) for x in per_dev_b]
    cap = max(1, max(a.shape[0] for a in cat_a))
    rows_a = np.zeros((n_dev, cap), np.int32)
    rows_b = np.zeros((n_dev, cap), np.int32)
    valid = np.zeros((n_dev, cap), bool)
    for d in range(n_dev):
        c = cat_a[d].shape[0]
        rows_a[d, :c] = cat_a[d]
        rows_b[d, :c] = cat_b[d]
        valid[d, :c] = True
    return rows_a, rows_b, valid


# ---------------------------------------------------------------------------
# Job 2: unified-executor shims
# ---------------------------------------------------------------------------

def match_catalog_dist(feats, catalog: TileCatalog, mesh: Mesh,
                       axis: str = "data", threshold: float = 0.8,
                       impl: str = "xla",
                       healthy: Optional[np.ndarray] = None,
                       chunk_tiles: int = 1024, schedule=None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1 of any self-join plan on a mesh: features (n, d) f32 in the
    blocked layout, row-sharded over ``axis``; each device all_gathers
    them and scores its tile shard. Thin shim over
    :func:`compiler.execute` (mode "self"); pass ``schedule=`` for
    cost-LPT placement instead of the reducer round-robin. Returns the
    compacted stage-1 survivor candidates (rows_a, rows_b) as host int64
    arrays; run stage 2 with ``compiler.verify_pairs``."""
    return execute(catalog, feats, threshold=threshold, impl=impl,
                   mesh=mesh, axis=axis, healthy=healthy,
                   chunk_tiles=chunk_tiles, schedule=schedule)


def make_catalog_2src_scorer(mesh: Mesh, axis: str = "data", *,
                             threshold: float, block_m: int = 128,
                             block_n: int = 128, impl: str = "xla"):
    """ONE jitted sharded-index scorer for query-vs-corpus catalogs:
    corpus row-sharded and gathered, query batch replicated — see
    :func:`compiler.make_scorer` (mode "cross"). Build it once per
    resident service and reuse it for every micro-batch."""
    return make_scorer(mesh, axis, mode="cross", threshold=threshold,
                       block_m=block_m, block_n=block_n, impl=impl)


def score_tiles_2src(scorer, feats_a, feats_b, tiles_dev: np.ndarray,
                     chunk: int, bm: int, bn: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Drive a :func:`make_catalog_2src_scorer` over per-device tile
    shards, ``chunk`` tiles per device at a time (``tiles_dev`` must be
    pre-padded via :func:`pad_device_tiles` so every chunk has one shape),
    compacting each chunk's masks into global (rows_a, rows_b)."""
    return _score_and_compact(scorer, (feats_a, jnp.asarray(feats_b)),
                              tiles_dev, chunk, bm, bn)


def match_catalog_2src_dist(feats_a, feats_b, catalog: TileCatalog,
                            mesh: Mesh, axis: str = "data",
                            threshold: float = 0.8, impl: str = "xla",
                            healthy: Optional[np.ndarray] = None,
                            chunk_tiles: int = 1024, schedule=None
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """One-shot sharded-index cross matcher: stage 1 of a two-source
    catalog with the corpus (a-side) row-sharded over ``axis`` and the
    query batch (b-side) replicated. Builds a fresh scorer — resident
    services should hold a :func:`make_catalog_2src_scorer` instead and
    pass it to :func:`compiler.execute`."""
    return execute(catalog, feats_a, feats_b, threshold=threshold,
                   impl=impl, mesh=mesh, axis=axis, healthy=healthy,
                   chunk_tiles=chunk_tiles, schedule=schedule,
                   fixed_chunks=True)


def sn_replication_volume(n: int, w: int, n_dev: int, feature_dim: int,
                          itemsize: int = 4, per_hop: bool = False):
    """Job-2 interconnect bytes *received* across all devices:
    (boundary replication, full all-gather) — or, with ``per_hop``, the
    per-device hop-by-hop byte schedule of the multi-hop halo chain.

    RepSN replicates only the w−1 boundary rows between adjacent shards —
    O(n_dev · w · d) — where the generic executors all_gather the whole
    feature matrix, O(n_dev · n · d). The gap is the SN analog of the
    paper's map-output-replication accounting (Fig. 12). The accounting
    matches the executor at ANY window size: when w − 1 > n/n_dev the
    halo crosses ⌈(w−1)/n_loc⌉ shards via chained hops, but the last hop
    forwards only the final partial strip, so the total stays exactly
    n_dev · (w−1) · d · itemsize — ``per_hop=True`` returns the
    per-device hop list [n_loc·row_bytes, …, take·row_bytes] summing to
    (w−1) · d · itemsize (the 2-tuple form sums it across devices).
    """
    halo = _w_eff(n, w) - 1
    if n_dev <= 1:          # single device: the halo ppermute is a
        return ([] if per_hop else (0, 0))   # self-send — nothing
    n_loc = n // n_dev      # crosses the wire
    if per_hop:
        return halo_bytes_per_device(n_loc, halo, feature_dim, itemsize)
    return (n_dev * halo * feature_dim * itemsize,
            n_dev * (n - n_loc) * feature_dim * itemsize)


def match_sn_dist(feats, w: int, mesh: Mesh, axis: str = "data",
                  threshold: float = 0.8, impl: str = "xla",
                  block_m: int = 128, block_n: int = 128,
                  chunk_tiles: int = 1024) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1 of Sorted Neighborhood on a mesh, RepSN-style.

    feats (n, d) f32 in *sorted-key order*, row-sharded over ``axis``
    (n must divide evenly). Device d owns every band pair whose smaller
    sorted position falls in its shard, and fetches only the w−1 boundary
    rows of the *next* shard with a neighbor ``ppermute`` — no all-gather
    (:func:`sn_replication_volume` accounts the byte gap). The shard's
    band job is compiled host-side in shard-local coordinates over the
    concatenated [local ‖ halo] strip (all catalog predicates are
    translation-invariant comparisons, and the band itself only depends
    on col − row) as ONE banded task per device with reducer = device —
    the compiler lowers/routes it like any other MatchJob, and the "halo"
    executor mode replaces the all-gather; the wrapped halo of the last
    device is masked out by its task's column window.

    Any window size: when w − 1 > n/n_dev the halo spans several shards
    and the scorer chains ⌈(w−1)/n_loc⌉ neighbor hops (the last hop
    forwards only the final partial strip, so each device still receives
    exactly w−1 rows — ``sn_replication_volume(per_hop=True)`` is the
    schedule). Returns compacted stage-1 survivor candidates
    (rows_a, rows_b) as sorted-order host int64 arrays; run stage 2
    with ``compiler.verify_pairs``.
    """
    n, _ = feats.shape
    n_dev = int(mesh.shape[axis])
    if n % n_dev:
        raise ValueError(f"n={n} not divisible by n_dev={n_dev}")
    n_loc = n // n_dev
    we = _w_eff(n, w)
    halo = we - 1

    rows = []
    for dev in range(n_dev):
        c1 = min(n - dev * n_loc, n_loc + halo)   # last shard: mask the wrap
        rows.append(task_row(0, n_loc, 1, c1 - 1, True, dev, band=we))
    # total_pairs = 0: per-shard band pair counts are owned by the
    # SortedNeighborhoodPlan; this job is routing geometry only.
    job: MatchJob = make_job(rows, n_loc + halo, n_loc + halo, n_dev, 0)
    catalog = lower(job, block_m, block_n)
    base = np.arange(n_dev, dtype=np.int64) * n_loc
    return execute(catalog, feats, threshold=threshold, impl=impl,
                   mesh=mesh, axis=axis, chunk_tiles=chunk_tiles,
                   halo=halo, base=base)


# ---------------------------------------------------------------------------
# Legacy executors (comparison baselines)
# ---------------------------------------------------------------------------

def _match_local(feats, codes, lens, ra, rb, valid, threshold, margin):
    mask, score = two_stage_match(
        feats[ra], feats[rb], codes[ra], lens[ra], codes[rb], lens[rb],
        threshold=threshold, filter_margin=margin)
    mask = mask & valid
    return mask, jnp.where(mask, score, 0.0)


def match_pair_range_dist(feats, codes, lens, plan: PairRangePlan,
                          mesh: Mesh, axis: str = "data",
                          threshold: float = 0.8, filter_margin: float = 0.25):
    """PairRange on a mesh, fully in-jit.

    feats (n, d) f32 / codes (n, L) uint8 / lens (n,) i32 are in the
    *blocked layout*, row-sharded over ``axis``. Every device owns the
    contiguous pair range [d·cap, (d+1)·cap) with cap = ⌈P/n_dev⌉ — the
    paper's eq. (2) with r = n_dev (additional logical ranges per device
    compose by concatenation since ranges are contiguous in p).

    Returns (rows_a, rows_b, mask, score), each (n_dev, cap), replicated
    row-block d holding device d's results.
    """
    n_dev = int(np.prod([mesh.shape[a] for a in (axis,)]))
    total = int(plan.total_pairs)
    cap = max(1, -(-total // n_dev))
    sizes = jnp.asarray(plan.block_sizes, jnp.int32)
    offsets = jnp.asarray(plan.offsets, jnp.int32)
    estart = jnp.asarray(plan.estart, jnp.int32)

    def job2(feats_l, codes_l, lens_l):
        d = jax.lax.axis_index(axis)
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        codes_g = jax.lax.all_gather(codes_l, axis, tiled=True)
        lens_g = jax.lax.all_gather(lens_l, axis, tiled=True)
        lo = (d * cap).astype(jnp.int32)
        ra, rb, valid = pairs_of_range_jnp(sizes, offsets, estart, lo, cap, total)
        mask, score = _match_local(
            feats_g, codes_g, lens_g, ra, rb, valid, threshold, filter_margin)
        out = lambda x: x[None]  # (1, cap) per device → (n_dev, cap) stacked
        return out(ra), out(rb), out(mask), out(score)

    shard = _smap(job2, mesh,
                  in_specs=(P(axis), P(axis), P(axis)),
                  out_specs=(P(axis), P(axis), P(axis), P(axis)))
    return shard(feats, codes, lens)


def match_shards_hostplan(feats, codes, lens, rows_a, rows_b, valid,
                          mesh: Mesh, axis: str = "data",
                          threshold: float = 0.8, filter_margin: float = 0.25):
    """LEGACY executor: per-device padded row pairs (from
    :func:`plan_rows_for_devices`), row-sharded features — O(P) host
    memory. Kept as a comparison baseline; use :func:`match_catalog_dist`
    for the O(#tiles) fused path."""

    def job2(feats_l, codes_l, lens_l, ra, rb, v):
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        codes_g = jax.lax.all_gather(codes_l, axis, tiled=True)
        lens_g = jax.lax.all_gather(lens_l, axis, tiled=True)
        mask, score = _match_local(
            feats_g, codes_g, lens_g, ra[0], rb[0], v[0],
            threshold, filter_margin)
        return mask[None], score[None]

    shard = _smap(job2, mesh,
                  in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis)),
                  out_specs=(P(axis), P(axis)))
    return shard(feats, codes, lens, rows_a, rows_b, valid)
