"""Distributed ER runtime: the paper's two MR jobs as shard_map programs.

Mapping (DESIGN.md §2): input partition Π_i ↔ per-device row shard on the
``data`` (× ``pod``) mesh axis; the shuffle ↔ ``all_gather`` on ICI; a
reduce task ↔ a work shard executed by one device. The number of logical
reduce tasks ``r`` stays decoupled from the device count ``n_dev`` exactly
as in the paper (r = 10·n there): device ``d`` executes reducers
``{k : k mod n_dev = d}`` (round-robin), which is also the straggler/
elasticity unit — see :func:`device_assignment`.

Job 1 (:func:`compute_bdm_sharded`): each device bincounts its local
blocking keys — its BDM *column* — then one ``all_gather`` produces the
full b × m matrix, replicated. This is Alg. 3 with the footnote-2 combiner
(the local bincount) built in.

Job 2, three executors:
  * :func:`match_catalog_dist` — THE generic fused path (any strategy):
    the host compiles the plan to a tile catalog (er/executor.py), tiles
    are routed reducer → device round-robin, and every device scores its
    padded tile shard with the catalog kernel over the all-gathered
    features. O(#tiles) metadata crosses the host/device boundary, never
    O(P) pair indices; stage-2 verify runs host-side on the compacted
    survivors.
  * :func:`match_pair_range_dist` — PairRange fully in-jit: every device
    derives its own pair list from the tiny replicated plan arrays
    (sizes/offsets/estart) via the closed-form inverse — the paper's
    map-side "relevant ranges" computation. No host-side pair
    materialization; essential at DS2 scale (6.7·10⁹ pairs).
  * :func:`match_shards_hostplan` — legacy executor for Basic/BlockSplit
    (per-device padded row-index arrays, O(P) host memory). Kept for
    comparison benchmarks; new callers should use the catalog path.
  * :func:`match_sn_dist` — Sorted Neighborhood, RepSN-style: each device
    owns the band pairs starting in its shard and replicates only the
    w−1 boundary rows of the next shard (neighbor ``ppermute``) instead
    of all-gathering — O(n_dev·w·d) interconnect bytes vs O(n_dev·n·d)
    (:func:`sn_replication_volume`).

The first three all_gather the (row-sharded) feature/code tensors — the
collective-volume analog of the paper's map-output replication (Fig. 12);
the benchmarks account it in bytes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.pair_range import PairRangePlan, pairs_of_range_jnp
from ..core.sorted_neighborhood import _w_eff
from .executor import A_TILE, B_TILE, NCOLS, RED, TileCatalog, _task_tiles
from .similarity import two_stage_match

__all__ = [
    "compute_bdm_sharded",
    "match_catalog_dist",
    "match_catalog_2src_dist",
    "make_catalog_2src_scorer",
    "score_tiles_2src",
    "match_pair_range_dist",
    "match_sn_dist",
    "match_shards_hostplan",
    "device_assignment",
    "plan_rows_for_devices",
    "plan_tiles_for_devices",
    "pad_device_tiles",
    "sn_replication_volume",
]


# shard_map moved from jax.experimental to the top-level namespace (with
# check_rep renamed check_vma) across the jax versions we support; the
# call sites below go through this shim.
try:
    _shard_map_new = jax.shard_map

    def _smap(f, mesh, in_specs, out_specs):
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def _smap(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# Job 1: BDM
# ---------------------------------------------------------------------------

def compute_bdm_sharded(block_ids, num_blocks: int, mesh: Mesh,
                        axis: str = "data"):
    """block_ids: (n,) int32 sharded over ``axis``; one device shard = one
    input partition Π_i. Returns the replicated (b, m) BDM, m = axis size."""

    def job1(local_ids):
        col = jnp.bincount(local_ids.reshape(-1), length=num_blocks)
        cols = jax.lax.all_gather(col, axis)          # (m, b)
        return cols.T.astype(jnp.int32)               # (b, m)

    shard = _smap(job1, mesh, in_specs=P(axis), out_specs=P())
    return shard(block_ids)


# ---------------------------------------------------------------------------
# Reduce-task → device round-robin (straggler / elasticity unit)
# ---------------------------------------------------------------------------

def device_assignment(r: int, n_dev: int,
                      healthy: Optional[np.ndarray] = None) -> np.ndarray:
    """reducer k → device. Round-robin over the *healthy* devices, so a
    failed/straggling device's work shards re-spread evenly — the plan is a
    pure function of (r, healthy mask), recomputable anywhere (the BDM
    restart argument, DESIGN.md §3)."""
    if healthy is None:
        healthy = np.ones(n_dev, bool)
    alive = np.flatnonzero(healthy)
    if alive.size == 0:
        raise ValueError("no healthy devices")
    return alive[np.arange(r) % alive.size]


def plan_rows_for_devices(reducer_rows, r: int, n_dev: int,
                          healthy: Optional[np.ndarray] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Concatenate per-reducer (rows_a, rows_b) into per-device padded
    arrays (n_dev, cap). Returns (rows_a, rows_b, valid)."""
    dev_of = device_assignment(r, n_dev, healthy)
    per_dev_a = [[] for _ in range(n_dev)]
    per_dev_b = [[] for _ in range(n_dev)]
    for k in range(r):
        ra, rb = reducer_rows[k]
        d = int(dev_of[k])
        per_dev_a[d].append(np.asarray(ra, np.int32))
        per_dev_b[d].append(np.asarray(rb, np.int32))
    cat_a = [np.concatenate(x) if x else np.zeros(0, np.int32) for x in per_dev_a]
    cat_b = [np.concatenate(x) if x else np.zeros(0, np.int32) for x in per_dev_b]
    cap = max(1, max(a.shape[0] for a in cat_a))
    rows_a = np.zeros((n_dev, cap), np.int32)
    rows_b = np.zeros((n_dev, cap), np.int32)
    valid = np.zeros((n_dev, cap), bool)
    for d in range(n_dev):
        c = cat_a[d].shape[0]
        rows_a[d, :c] = cat_a[d]
        rows_b[d, :c] = cat_b[d]
        valid[d, :c] = True
    return rows_a, rows_b, valid


def plan_tiles_for_devices(catalog: TileCatalog, n_dev: int,
                           healthy: Optional[np.ndarray] = None) -> np.ndarray:
    """Partition a tile catalog over devices: reducer → device round-robin
    (:func:`device_assignment`), per-device tile lists padded to a common
    cap with all-zero entries (empty validity window → no survivors).
    Returns (n_dev, cap, NCOLS) int32 — O(#tiles) metadata, the only
    plan state that crosses the host/device boundary."""
    dev_of = device_assignment(catalog.r, n_dev, healthy)
    dev = dev_of[catalog.tiles[:, RED]] if catalog.num_tiles else \
        np.zeros(0, np.int64)
    counts = np.bincount(dev, minlength=n_dev)
    cap = max(1, int(counts.max()) if counts.size else 1)
    out = np.zeros((n_dev, cap, NCOLS), np.int32)
    for d in range(n_dev):
        mine = catalog.tiles[dev == d]
        out[d, :mine.shape[0]] = mine
    return out


# ---------------------------------------------------------------------------
# Job 2 executors
# ---------------------------------------------------------------------------

def _pad_tile_chunks(tiles_dev: np.ndarray,
                     chunk_tiles: int) -> Tuple[np.ndarray, int]:
    """Pad the per-device tile cap to a chunk multiple (zero entries have
    an empty validity window → no survivors) so every chunk traces with
    one shape. Returns (padded tiles, chunk size)."""
    n_dev, cap = tiles_dev.shape[:2]
    chunk = min(chunk_tiles, max(cap, 1))
    pad = (-cap) % chunk
    if pad:
        tiles_dev = np.concatenate(
            [tiles_dev, np.zeros((n_dev, pad, NCOLS), np.int32)], axis=1)
    return tiles_dev, chunk


def _score_and_compact(shard, feats, tiles_dev, chunk: int, bm: int, bn: int,
                       base: Optional[np.ndarray] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Drive a jitted per-shard catalog scorer chunk by chunk and compact
    each chunk's (n_dev, chunk, bm, bn) survivor masks into global
    (rows_a, rows_b) — host memory stays O(n_dev · chunk · bm · bn)
    regardless of plan size. ``feats`` is one array or a tuple of scorer
    operands (the two-source path passes (corpus, queries)); ``base``
    (n_dev,) shifts device-local tile coordinates to global rows (the
    RepSN local-coordinate path); None means the tiles already carry
    global strip indices."""
    operands = feats if isinstance(feats, tuple) else (feats,)
    cap = tiles_dev.shape[1]
    out_a, out_b = [], []
    for lo in range(0, cap, chunk):
        part = tiles_dev[:, lo:lo + chunk]
        masks = np.asarray(shard(*operands, jnp.asarray(part)))
        d, ti, ii, jj = np.nonzero(masks)
        off = base[d] if base is not None else 0
        out_a.append(off + part[d, ti, A_TILE].astype(np.int64) * bm + ii)
        out_b.append(off + part[d, ti, B_TILE].astype(np.int64) * bn + jj)
    if not out_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(out_a), np.concatenate(out_b)


def _match_local(feats, codes, lens, ra, rb, valid, threshold, margin):
    mask, score = two_stage_match(
        feats[ra], feats[rb], codes[ra], lens[ra], codes[rb], lens[rb],
        threshold=threshold, filter_margin=margin)
    mask = mask & valid
    return mask, jnp.where(mask, score, 0.0)


def match_catalog_dist(feats, catalog: TileCatalog, mesh: Mesh,
                       axis: str = "data", threshold: float = 0.8,
                       impl: str = "xla",
                       healthy: Optional[np.ndarray] = None,
                       chunk_tiles: int = 1024
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1 of any plan on a mesh via the tile-catalog executor.

    feats (n, d) f32 in the blocked layout, row-sharded over ``axis``.
    Each device all_gathers the features and scores its tile shard
    (reducer → device round-robin, elasticity via ``healthy``) with the
    catalog kernel — the per-device work is exactly the plan's reducer
    loads, so the makespan IS the paper's balance metric. Tile shards are
    processed ``chunk_tiles`` per device at a time and each chunk's
    survivor masks are compacted immediately, so host memory stays
    O(n_dev · chunk_tiles · bm · bn) regardless of plan size. Returns the
    compacted stage-1 survivor candidates (rows_a, rows_b) as host int64
    arrays; run stage 2 with ``executor.verify_pairs``.

    ``impl="xla"`` (default) is shard_map-safe everywhere; pass "pallas"
    on a TPU backend to run the fused kernel per device.
    """
    from ..kernels import ops

    n_dev = int(np.prod([mesh.shape[a] for a in (axis,)]))
    bm, bn = catalog.block_m, catalog.block_n
    tiles_dev, chunk = _pad_tile_chunks(
        plan_tiles_for_devices(catalog, n_dev, healthy), chunk_tiles)

    def job2(feats_l, tiles_l):
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        mask = ops.pair_scores_catalog(
            feats_g, feats_g, tiles_l[0], threshold=threshold,
            block_m=bm, block_n=bn, impl=impl)
        return mask[None]

    shard = jax.jit(_smap(job2, mesh, in_specs=(P(axis), P(axis)),
                          out_specs=P(axis)))
    return _score_and_compact(shard, feats, tiles_dev, chunk, bm, bn)


def pad_device_tiles(tiles_dev: np.ndarray, chunk: int) -> np.ndarray:
    """Pad the per-device tile cap UP to a multiple of ``chunk`` (>= one
    full chunk) with all-zero entries, so every chunk a scorer sees has
    the exact shape (n_dev, chunk, NCOLS) — unlike :func:`_pad_tile_chunks`
    which shrinks the chunk to the cap. This is the fixed-shape contract
    the resident service's recompile guard depends on."""
    n_dev, cap = tiles_dev.shape[:2]
    padded = max(chunk, -(-cap // chunk) * chunk)
    if padded != cap:
        tiles_dev = np.concatenate(
            [tiles_dev, np.zeros((n_dev, padded - cap, NCOLS), np.int32)],
            axis=1)
    return tiles_dev


def make_catalog_2src_scorer(mesh: Mesh, axis: str = "data", *,
                             threshold: float, block_m: int = 128,
                             block_n: int = 128, impl: str = "xla"):
    """Build ONE jitted sharded-index scorer for query-vs-corpus catalogs.

    Data flow (the service's sharded-index variant): the corpus feature
    matrix is row-sharded over ``axis`` (each device owns a corpus
    shard), the query batch is replicated (broadcast — micro-batches are
    tiny next to the corpus), tile shards route reducer → device
    round-robin exactly as in :func:`match_catalog_dist`, and each device
    all_gathers the corpus shard ring to score its tiles against the full
    blocked layout (blocks span shard boundaries, so the gather is the
    shuffle, as in the paper).

    Returns ``scorer(corpus_feats_sharded, query_feats, tiles_chunk)`` →
    (n_dev, chunk, bm, bn) survivor masks. Build it once per resident
    service and reuse it for every micro-batch: jit caches by the wrapped
    function's identity, so a per-call closure would retrace every batch.
    """
    from ..kernels import ops

    def job2(feats_l, feats_q, tiles_l):
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        mask = ops.pair_scores_catalog(
            feats_g, feats_q, tiles_l[0], threshold=threshold,
            block_m=block_m, block_n=block_n, impl=impl)
        return mask[None]

    return jax.jit(_smap(job2, mesh, in_specs=(P(axis), P(), P(axis)),
                         out_specs=P(axis)))


def score_tiles_2src(scorer, feats_a, feats_b, tiles_dev: np.ndarray,
                     chunk: int, bm: int, bn: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Drive a :func:`make_catalog_2src_scorer` over per-device tile
    shards, ``chunk`` tiles per device at a time (``tiles_dev`` must be
    pre-padded via :func:`pad_device_tiles` so every chunk has one shape),
    compacting each chunk's masks into global (rows_a, rows_b)."""
    return _score_and_compact(scorer, (feats_a, jnp.asarray(feats_b)),
                              tiles_dev, chunk, bm, bn)


def match_catalog_2src_dist(feats_a, feats_b, catalog: TileCatalog,
                            mesh: Mesh, axis: str = "data",
                            threshold: float = 0.8, impl: str = "xla",
                            healthy: Optional[np.ndarray] = None,
                            chunk_tiles: int = 1024
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """One-shot sharded-index cross matcher: stage 1 of a two-source
    catalog with the corpus (a-side) row-sharded over ``axis`` and the
    query batch (b-side) replicated. Builds a fresh scorer — resident
    services should hold a :func:`make_catalog_2src_scorer` instead and
    drive it through :func:`score_tiles_2src`."""
    n_dev = int(mesh.shape[axis])
    scorer = make_catalog_2src_scorer(
        mesh, axis, threshold=threshold, block_m=catalog.block_m,
        block_n=catalog.block_n, impl=impl)
    tiles_dev = pad_device_tiles(
        plan_tiles_for_devices(catalog, n_dev, healthy), chunk_tiles)
    return score_tiles_2src(scorer, feats_a, feats_b, tiles_dev,
                            chunk_tiles, catalog.block_m, catalog.block_n)


def sn_replication_volume(n: int, w: int, n_dev: int, feature_dim: int,
                          itemsize: int = 4) -> Tuple[int, int]:
    """Job-2 interconnect bytes *received* across all devices:
    (boundary replication, full all-gather).

    RepSN replicates only the w−1 boundary rows between adjacent shards —
    O(n_dev · w · d) — where the generic executors all_gather the whole
    feature matrix, O(n_dev · n · d). The gap is the SN analog of the
    paper's map-output-replication accounting (Fig. 12).
    """
    if n_dev <= 1:          # single device: the halo ppermute is a
        return 0, 0         # self-send — nothing crosses the wire
    n_loc = n // n_dev
    halo = max(min(w, n) - 1, 0)
    return (n_dev * halo * feature_dim * itemsize,
            n_dev * (n - n_loc) * feature_dim * itemsize)


def match_sn_dist(feats, w: int, mesh: Mesh, axis: str = "data",
                  threshold: float = 0.8, impl: str = "xla",
                  block_m: int = 128, block_n: int = 128,
                  chunk_tiles: int = 1024) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1 of Sorted Neighborhood on a mesh, RepSN-style.

    feats (n, d) f32 in *sorted-key order*, row-sharded over ``axis``
    (n must divide evenly). Device d owns every band pair whose smaller
    sorted position falls in its shard, and fetches only the w−1 boundary
    rows of the *next* shard with a neighbor ``ppermute`` — no all-gather
    (:func:`sn_replication_volume` accounts the byte gap). The shard's
    band tiles are compiled host-side in shard-local coordinates over the
    concatenated [local ‖ halo] strip (all catalog predicates are
    translation-invariant comparisons, and the band itself only depends
    on col − row) and scored with the catalog kernel; the wrapped halo of
    the last device is masked out by its tiles' column windows.

    Single-hop halo: requires w − 1 ≤ n/n_dev. Returns compacted stage-1
    survivor candidates (rows_a, rows_b) as sorted-order host int64
    arrays; run stage 2 with ``executor.verify_pairs``.
    """
    from ..kernels import ops

    n, _ = feats.shape
    n_dev = int(mesh.shape[axis])
    if n % n_dev:
        raise ValueError(f"n={n} not divisible by n_dev={n_dev}")
    n_loc = n // n_dev
    we = _w_eff(n, w)
    halo = we - 1
    if halo > n_loc:
        raise ValueError(
            f"window {w} needs {halo} boundary rows > shard size {n_loc} "
            "(multi-hop halo exchange not implemented)")

    per_dev = []
    for dev in range(n_dev):
        c1 = min(n - dev * n_loc, n_loc + halo)   # last shard: mask the wrap
        per_dev.append(_task_tiles(0, n_loc, 1, c1 - 1, True, dev,
                                   block_m, block_n, band=we))
    cap = max(1, max(t.shape[0] for t in per_dev))
    tiles_dev = np.zeros((n_dev, cap, NCOLS), np.int32)
    for dev, t in enumerate(per_dev):
        tiles_dev[dev, :t.shape[0]] = t
    tiles_dev, chunk = _pad_tile_chunks(tiles_dev, chunk_tiles)

    perm = [(s, (s - 1) % n_dev) for s in range(n_dev)]

    def job2(feats_l, tiles_l):
        if halo:
            nbr = jax.lax.ppermute(feats_l[:halo], axis, perm)
            feats_cat = jnp.concatenate([feats_l, nbr], axis=0)
        else:
            feats_cat = feats_l
        mask = ops.pair_scores_catalog(
            feats_cat, feats_cat, tiles_l[0], threshold=threshold,
            block_m=block_m, block_n=block_n, impl=impl)
        return mask[None]

    shard = jax.jit(_smap(job2, mesh, in_specs=(P(axis), P(axis)),
                          out_specs=P(axis)))
    base = np.arange(n_dev, dtype=np.int64) * n_loc
    return _score_and_compact(shard, feats, tiles_dev, chunk,
                              block_m, block_n, base=base)


def match_pair_range_dist(feats, codes, lens, plan: PairRangePlan,
                          mesh: Mesh, axis: str = "data",
                          threshold: float = 0.8, filter_margin: float = 0.25):
    """PairRange on a mesh, fully in-jit.

    feats (n, d) f32 / codes (n, L) uint8 / lens (n,) i32 are in the
    *blocked layout*, row-sharded over ``axis``. Every device owns the
    contiguous pair range [d·cap, (d+1)·cap) with cap = ⌈P/n_dev⌉ — the
    paper's eq. (2) with r = n_dev (additional logical ranges per device
    compose by concatenation since ranges are contiguous in p).

    Returns (rows_a, rows_b, mask, score), each (n_dev, cap), replicated
    row-block d holding device d's results.
    """
    n_dev = int(np.prod([mesh.shape[a] for a in (axis,)]))
    total = int(plan.total_pairs)
    cap = max(1, -(-total // n_dev))
    sizes = jnp.asarray(plan.block_sizes, jnp.int32)
    offsets = jnp.asarray(plan.offsets, jnp.int32)
    estart = jnp.asarray(plan.estart, jnp.int32)

    def job2(feats_l, codes_l, lens_l):
        d = jax.lax.axis_index(axis)
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        codes_g = jax.lax.all_gather(codes_l, axis, tiled=True)
        lens_g = jax.lax.all_gather(lens_l, axis, tiled=True)
        lo = (d * cap).astype(jnp.int32)
        ra, rb, valid = pairs_of_range_jnp(sizes, offsets, estart, lo, cap, total)
        mask, score = _match_local(
            feats_g, codes_g, lens_g, ra, rb, valid, threshold, filter_margin)
        out = lambda x: x[None]  # (1, cap) per device → (n_dev, cap) stacked
        return out(ra), out(rb), out(mask), out(score)

    shard = _smap(job2, mesh,
                  in_specs=(P(axis), P(axis), P(axis)),
                  out_specs=(P(axis), P(axis), P(axis), P(axis)))
    return shard(feats, codes, lens)


def match_shards_hostplan(feats, codes, lens, rows_a, rows_b, valid,
                          mesh: Mesh, axis: str = "data",
                          threshold: float = 0.8, filter_margin: float = 0.25):
    """LEGACY executor: per-device padded row pairs (from
    :func:`plan_rows_for_devices`), row-sharded features — O(P) host
    memory. Kept as a comparison baseline; use :func:`match_catalog_dist`
    for the O(#tiles) fused path."""

    def job2(feats_l, codes_l, lens_l, ra, rb, v):
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        codes_g = jax.lax.all_gather(codes_l, axis, tiled=True)
        lens_g = jax.lax.all_gather(lens_l, axis, tiled=True)
        mask, score = _match_local(
            feats_g, codes_g, lens_g, ra[0], rb[0], v[0],
            threshold, filter_margin)
        return mask[None], score[None]

    shard = _smap(job2, mesh,
                  in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis)),
                  out_specs=(P(axis), P(axis)))
    return shard(feats, codes, lens, rows_a, rows_b, valid)
