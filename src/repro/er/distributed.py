"""Distributed ER runtime: the paper's two MR jobs as shard_map programs.

Mapping (DESIGN.md §2): input partition Π_i ↔ per-device row shard on the
``data`` (× ``pod``) mesh axis; the shuffle ↔ ``all_gather`` on ICI; a
reduce task ↔ a work shard executed by one device. The number of logical
reduce tasks ``r`` stays decoupled from the device count ``n_dev`` exactly
as in the paper (r = 10·n there): device ``d`` executes reducers
``{k : k mod n_dev = d}`` (round-robin), which is also the straggler/
elasticity unit — see :func:`device_assignment`.

Job 1 (:func:`compute_bdm_sharded`): each device bincounts its local
blocking keys — its BDM *column* — then one ``all_gather`` produces the
full b × m matrix, replicated. This is Alg. 3 with the footnote-2 combiner
(the local bincount) built in.

Job 2, two executors:
  * :func:`match_pair_range_dist` — PairRange fully in-jit: every device
    derives its own pair list from the tiny replicated plan arrays
    (sizes/offsets/estart) via the closed-form inverse — the paper's
    map-side "relevant ranges" computation. No host-side pair
    materialization; essential at DS2 scale (6.7·10⁹ pairs).
  * :func:`match_shards_hostplan` — generic executor for Basic/BlockSplit:
    the host plan (the map phase) emits per-device padded row-index
    arrays; devices gather the rows and match.

Both all_gather the (row-sharded) feature/code tensors — the collective-
volume analog of the paper's map-output replication (Fig. 12); the
benchmarks account it in bytes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.pair_range import PairRangePlan, pairs_of_range_jnp
from .similarity import two_stage_match

__all__ = [
    "compute_bdm_sharded",
    "match_pair_range_dist",
    "match_shards_hostplan",
    "device_assignment",
    "plan_rows_for_devices",
]


# ---------------------------------------------------------------------------
# Job 1: BDM
# ---------------------------------------------------------------------------

def compute_bdm_sharded(block_ids, num_blocks: int, mesh: Mesh,
                        axis: str = "data"):
    """block_ids: (n,) int32 sharded over ``axis``; one device shard = one
    input partition Π_i. Returns the replicated (b, m) BDM, m = axis size."""

    def job1(local_ids):
        col = jnp.bincount(local_ids.reshape(-1), length=num_blocks)
        cols = jax.lax.all_gather(col, axis)          # (m, b)
        return cols.T.astype(jnp.int32)               # (b, m)

    shard = jax.shard_map(
        job1, mesh=mesh,
        in_specs=P(axis), out_specs=P(),
        check_vma=False)  # all_gather output is replicated by construction
    return shard(block_ids)


# ---------------------------------------------------------------------------
# Reduce-task → device round-robin (straggler / elasticity unit)
# ---------------------------------------------------------------------------

def device_assignment(r: int, n_dev: int,
                      healthy: Optional[np.ndarray] = None) -> np.ndarray:
    """reducer k → device. Round-robin over the *healthy* devices, so a
    failed/straggling device's work shards re-spread evenly — the plan is a
    pure function of (r, healthy mask), recomputable anywhere (the BDM
    restart argument, DESIGN.md §3)."""
    if healthy is None:
        healthy = np.ones(n_dev, bool)
    alive = np.flatnonzero(healthy)
    if alive.size == 0:
        raise ValueError("no healthy devices")
    return alive[np.arange(r) % alive.size]


def plan_rows_for_devices(reducer_rows, r: int, n_dev: int,
                          healthy: Optional[np.ndarray] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Concatenate per-reducer (rows_a, rows_b) into per-device padded
    arrays (n_dev, cap). Returns (rows_a, rows_b, valid)."""
    dev_of = device_assignment(r, n_dev, healthy)
    per_dev_a = [[] for _ in range(n_dev)]
    per_dev_b = [[] for _ in range(n_dev)]
    for k in range(r):
        ra, rb = reducer_rows[k]
        d = int(dev_of[k])
        per_dev_a[d].append(np.asarray(ra, np.int32))
        per_dev_b[d].append(np.asarray(rb, np.int32))
    cat_a = [np.concatenate(x) if x else np.zeros(0, np.int32) for x in per_dev_a]
    cat_b = [np.concatenate(x) if x else np.zeros(0, np.int32) for x in per_dev_b]
    cap = max(1, max(a.shape[0] for a in cat_a))
    rows_a = np.zeros((n_dev, cap), np.int32)
    rows_b = np.zeros((n_dev, cap), np.int32)
    valid = np.zeros((n_dev, cap), bool)
    for d in range(n_dev):
        c = cat_a[d].shape[0]
        rows_a[d, :c] = cat_a[d]
        rows_b[d, :c] = cat_b[d]
        valid[d, :c] = True
    return rows_a, rows_b, valid


# ---------------------------------------------------------------------------
# Job 2 executors
# ---------------------------------------------------------------------------

def _match_local(feats, codes, lens, ra, rb, valid, threshold, margin):
    mask, score = two_stage_match(
        feats[ra], feats[rb], codes[ra], lens[ra], codes[rb], lens[rb],
        threshold=threshold, filter_margin=margin)
    mask = mask & valid
    return mask, jnp.where(mask, score, 0.0)


def match_pair_range_dist(feats, codes, lens, plan: PairRangePlan,
                          mesh: Mesh, axis: str = "data",
                          threshold: float = 0.8, filter_margin: float = 0.25):
    """PairRange on a mesh, fully in-jit.

    feats (n, d) f32 / codes (n, L) uint8 / lens (n,) i32 are in the
    *blocked layout*, row-sharded over ``axis``. Every device owns the
    contiguous pair range [d·cap, (d+1)·cap) with cap = ⌈P/n_dev⌉ — the
    paper's eq. (2) with r = n_dev (additional logical ranges per device
    compose by concatenation since ranges are contiguous in p).

    Returns (rows_a, rows_b, mask, score), each (n_dev, cap), replicated
    row-block d holding device d's results.
    """
    n_dev = int(np.prod([mesh.shape[a] for a in (axis,)]))
    total = int(plan.total_pairs)
    cap = max(1, -(-total // n_dev))
    sizes = jnp.asarray(plan.block_sizes, jnp.int32)
    offsets = jnp.asarray(plan.offsets, jnp.int32)
    estart = jnp.asarray(plan.estart, jnp.int32)

    def job2(feats_l, codes_l, lens_l):
        d = jax.lax.axis_index(axis)
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        codes_g = jax.lax.all_gather(codes_l, axis, tiled=True)
        lens_g = jax.lax.all_gather(lens_l, axis, tiled=True)
        lo = (d * cap).astype(jnp.int32)
        ra, rb, valid = pairs_of_range_jnp(sizes, offsets, estart, lo, cap, total)
        mask, score = _match_local(
            feats_g, codes_g, lens_g, ra, rb, valid, threshold, filter_margin)
        out = lambda x: x[None]  # (1, cap) per device → (n_dev, cap) stacked
        return out(ra), out(rb), out(mask), out(score)

    shard = jax.shard_map(
        job2, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
        check_vma=False)  # replicated plan constants mix with varying data
    return shard(feats, codes, lens)


def match_shards_hostplan(feats, codes, lens, rows_a, rows_b, valid,
                          mesh: Mesh, axis: str = "data",
                          threshold: float = 0.8, filter_margin: float = 0.25):
    """Generic executor: per-device padded row pairs (from
    :func:`plan_rows_for_devices`), row-sharded features. Used by Basic and
    BlockSplit (whose pair lists come from host tile geometry)."""

    def job2(feats_l, codes_l, lens_l, ra, rb, v):
        feats_g = jax.lax.all_gather(feats_l, axis, tiled=True)
        codes_g = jax.lax.all_gather(codes_l, axis, tiled=True)
        lens_g = jax.lax.all_gather(lens_l, axis, tiled=True)
        mask, score = _match_local(
            feats_g, codes_g, lens_g, ra[0], rb[0], v[0],
            threshold, filter_margin)
        return mask[None], score[None]

    shard = jax.shard_map(
        job2, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False)  # replicated plan constants mix with varying data
    return shard(feats, codes, lens, rows_a, rows_b, valid)
