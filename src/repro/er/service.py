"""Incremental ER service: a resident blocked index serving match traffic.

The paper's Job-1 BDM exists so that plans are cheap deterministic
functions of a tiny matrix — which means a corpus ingested ONCE can
answer "match these new entities" without re-sharding or replanning from
scratch. :class:`ERService` keeps the corpus resident (encoded features
in the blocked layout on device, BDM host-side) and serves
``match(query_titles)`` micro-batches:

  1. **Incremental BDM** (`core/bdm.update_bdm`): query keys fold into
     the host-side matrices as a monoid update; never-seen blocks append
     zero rows (the corpus side stays untouched — zero-size blocks plan
     zero pairs).
  2. **Two-source plan** (`core/two_source.plan_pair_range_2src` /
     `plan_block_split_2src`): each batch is a balanced query-vs-corpus
     R × S job over the shared block space — Kolb et al.'s Appendix-I
     formulation, finally wired end to end.
  3. **Unified compiler** (`er/compiler`): the plan lowers through the
     same `plan_to_job → lower → schedule_tiles → execute` pipeline as
     the batch run_er — rectangular MXU tiles, cost-LPT tile placement,
     the same fused kernel; exact stage-2 verify on survivors.
  4. **Shape buckets**: query batches pad to a small set of bucket sizes
     and catalogs pad to a fixed tile-chunk multiple, so steady-state
     traffic reuses a handful of compiled shapes — after :meth:`warmup`,
     serving triggers ZERO new XLA compilations (`compile_counter`
     asserts this in CI).
  5. **Sharded index** (``mesh=``): each device owns a corpus shard,
     query batches broadcast, tile shards route tiles → reducers →
     devices through the compiler's cost-LPT schedule
     (`compiler.schedule_tiles`) — the cross-mode scorer
     (`compiler.make_scorer`) is jitted once at construction, because a
     per-batch closure would retrace every call. With
     ``ServiceConfig.comms`` = "ring" | "hierarchical" a second pinned
     scorer replaces the flat corpus all-gather: keyed jobs plan a
     per-batch :func:`compiler.plan_comms` locality placement (zero
     hops — cross tiles never read outside their own strip), and jobs
     whose plan degrades fall back to the flat scorer, so the
     zero-recompile contract holds either way.

Entities without blocking keys follow the paper's decomposition,
restricted to cross pairs: null-key queries × whole corpus, plus
null-key corpus entities × the keyed queries (`catalog_for_cross`;
null × null pairs live in the first job only). The
streaming ≡ batch contract — the union of served matches over any batch
split equals a one-shot ``run_er`` over corpus ++ queries restricted to
cross pairs (`pipeline.cross_restrict`) — is the service's correctness
oracle.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field as dataclasses_field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import blocked_layout, compute_bdm, entity_indices, update_bdm
from ..core.two_source import (TwoSourceBDM, plan_block_split_2src,
                               plan_pair_range_2src)
from .blocking import prefix_key
from .compiler import (COMMS_POLICIES, GEOMETRY_LATTICE, DeviceKilledError,
                       EwmaCostModel, GeometryCostModel,
                       NoHealthyDevicesError, RecoveryFailedError,
                       SupervisedReport, TransientScorerError, TuneReport,
                       autotune, cross_job, default_group, execute,
                       execute_supervised, lower, make_scorer, pad_catalog,
                       plan_comms, plan_to_job, schedule_tiles, verify_pairs)
from .compiler.execute import _compact_on_device, _resolve_impl
from .compiler.faults import FaultInjector
from .pipeline import featurize

__all__ = ["ServiceConfig", "ERService", "MatchResponse",
           "ServiceUnavailable", "compile_counter"]


_COMPILE_EVENT_PREFIX = "/jax/core/compile/backend_compile"
_COUNTER_LOCK = threading.Lock()
_ACTIVE_COUNTERS: set = set()
_listener_registered = False


def _on_compile_event(name: str, *args, **kwargs):
    if name.startswith(_COMPILE_EVENT_PREFIX):
        with _COUNTER_LOCK:
            for counter in _ACTIVE_COUNTERS:
                counter.count += 1


def _unregister_compile_listener() -> bool:
    """Best-effort unregister (jax exposes the hook privately); returns
    whether the listener was actually removed."""
    try:
        from jax._src import monitoring as _monitoring
        _monitoring._unregister_event_duration_listener_by_callback(
            _on_compile_event)
        return True
    except Exception:
        return False


class compile_counter:
    """Count XLA backend compilations inside a ``with`` block via
    ``jax.monitoring`` duration events — cache hits emit none, so after a
    service warmup the steady-state count must be exactly zero (the
    recompile guard the tests and the serve benchmark assert).

    Thread-safe and re-entrant: the module-level listener is registered
    while any counter is live and unregistered when the last one exits
    (falling back to keep-registered on jax versions without the
    unregister hook), subscription and increments share one lock, and
    the same instance can be nested — the count resets only on the
    outermost ``__enter__``. Counters are global: a counter sees
    compilations triggered by *other* threads while it is open, which is
    exactly what a steady-state ZERO assertion wants."""

    def __init__(self):
        self.count = 0
        self._depth = 0

    def __enter__(self) -> "compile_counter":
        global _listener_registered
        with _COUNTER_LOCK:
            if self._depth == 0:
                self.count = 0
            self._depth += 1
            if not _listener_registered:
                jax.monitoring.register_event_duration_secs_listener(
                    _on_compile_event)
                _listener_registered = True
            _ACTIVE_COUNTERS.add(self)
        return self

    def __exit__(self, *exc):
        global _listener_registered
        with _COUNTER_LOCK:
            self._depth -= 1
            if self._depth <= 0:
                _ACTIVE_COUNTERS.discard(self)
                if not _ACTIVE_COUNTERS and _listener_registered \
                        and _unregister_compile_listener():
                    _listener_registered = False
        return False


class ServiceUnavailable(RuntimeError):
    """Clean service-level failure: every execution device is evicted
    (circuit breaker open) or died mid-request. Carries retry-after
    semantics — clients should back off ``retry_after_s`` seconds, by
    which time EVERY breaker cooldown will have elapsed and the next
    request will probe (and can re-admit) all evicted devices. Always
    computed from the live breaker state — there is deliberately no
    default, so no raise site can fall back to a made-up constant."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


class MatchResponse(set):
    """``ERService.match``'s result: behaves exactly like the historical
    ``set`` of (corpus_index, query_index) pairs, with degradation
    metadata on the side. ``coverage`` is live pairs scored / planned —
    1.0 on the quiet path and after any full recovery; < 1.0 only when
    the service returned partial results instead of failing."""

    def __init__(self, *args):
        super().__init__(*args)
        self.attempts = 1          # max supervisor rounds over the jobs
        self.recovered_tiles = 0   # tiles recovered on retry rounds
        self.degraded = False      # True iff coverage < 1.0
        self.planned_cost = 0      # live pairs planned across jobs
        self.scored_cost = 0       # live pairs actually scored
        self.steals = 0            # work-stealing events across the jobs
        self.stolen_tiles = 0      # queued tiles moved off slow devices

    @property
    def coverage(self) -> float:
        if self.planned_cost == 0:
            return 1.0
        return self.scored_cost / self.planned_cost

    def _fold(self, report: Optional[SupervisedReport]):
        if report is None:
            return
        self.attempts = max(self.attempts, report.rounds)
        self.recovered_tiles += report.recovered_tiles
        self.planned_cost += report.planned_cost
        self.scored_cost += report.scored_cost
        self.steals += report.steals
        self.stolen_tiles += report.stolen_tiles
        if report.lost_tiles:
            self.degraded = True


@dataclass
class _RequestContext:
    """Request-scoped execution state. One instance is created at the
    OUTER ``match`` entry and threaded through every slice and job of
    that request — the deadline is armed exactly once (an oversized
    batch's k slices share one budget instead of re-arming k fresh
    ones), and supervised reports accumulate here instead of on the
    service instance (where overlapping requests from the batcher's
    threads would clobber each other)."""
    deadline_at: Optional[float] = None   # absolute perf_counter deadline
    reports: List[SupervisedReport] = dataclasses_field(
        default_factory=list)


@dataclass
class _PlannedJob:
    """One lowered stage-1 job of a planned batch: everything the
    executor needs, with no remaining dependence on mutable host state.

    ``map_a``/``map_b`` translate survivor (a, b) coordinates back to
    (corpus_index, query_index_within_batch); ``map_a`` None means the
    a-side rows already are corpus indices."""
    feats_a: object
    catalog: object
    q_buf: np.ndarray
    codes_a: np.ndarray
    lens_a: np.ndarray
    codes_b: np.ndarray
    lens_b: np.ndarray
    map_a: Optional[np.ndarray]
    map_b: np.ndarray
    # Resolved comms plan for this job's catalog (mesh= with
    # cfg.comms != "flat" only): locality tile placement + buffer
    # origins for the pinned ring/hierarchical scorer. None routes the
    # job through the flat all-gather scorer.
    comms_plan: Optional[object] = None


@dataclass
class _PlannedBatch:
    """The host-side half of one batch: featurized queries planned,
    lowered and padded into fixed-shape jobs. Produced by
    ``ERService._plan_batch`` (under the service's host lock — it folds
    the batch into the vocab/BDM), consumed by ``_execute_batch``
    (device-side, lock-free). The split is what lets the batcher
    pipeline the next batch's planning under the current batch's
    kernels."""
    nq: int
    bucket: int
    t0: float
    record: bool
    planned: int                  # live pairs planned across the jobs
    jobs: List[_PlannedJob]


@dataclass
class ServiceConfig:
    strategy: str = "pair_range"          # two-source planner: pair_range
                                          # | block_split
    r: int = 16                           # reduce tasks per query job
    m: int = 8                            # corpus input partitions (BDM cols)
    threshold: float = 0.8
    filter_margin: float = 0.25
    prefix_len: int = 3
    feature_dim: int = 256
    max_len: int = 64
    match_missing_keys: bool = True
    block_m: int = 128                    # catalog tile rows
    block_n: int = 128                    # catalog tile cols
    kernel_impl: str = "auto"             # auto | pallas | interpret | xla
    query_buckets: Tuple[int, ...] = (8, 32, 128, 512)  # batch pad sizes
    tile_chunk: int = 256                 # fixed catalog chunk (tiles/launch)
    compact_capacity: Optional[int] = None  # stage-1 packed survivor slots
                                            # per tile; None = bm·bn (the
                                            # no-overflow default)
    schedule_policy: str = "cost_lpt"     # cost_lpt | round_robin
    comms: str = "flat"                   # mesh= gather policy for keyed
                                          # jobs: flat | ring |
                                          # hierarchical (DESIGN.md §Mesh
                                          # scale-out). Ignored without a
                                          # mesh; jobs whose plan degrades
                                          # run the flat scorer.
    # ---- fault tolerance (DESIGN.md §Fault tolerance) ----
    exec_devices: int = 0                 # > 0: supervised stage 1 over N
                                          # logical device shards
    request_deadline_s: Optional[float] = None  # per-request wall budget
    shard_deadline_s: Optional[float] = None    # per-shard straggler cutoff
    max_retries: int = 3                  # extra recovery rounds per job
    backoff_s: float = 0.02               # base retry backoff (exponential)
    backoff_factor: float = 2.0
    partial_results: bool = True          # degrade instead of failing
    breaker_threshold: int = 3            # consecutive failures → evict
    breaker_cooldown_s: float = 0.5       # probe an evicted device after this
    # ---- runtime feedback (DESIGN.md §Scheduling feedback loop) ----
    feedback_scheduling: bool = False     # EWMA-calibrate schedule_tiles
    steal_factor: Optional[float] = None  # > 0: mid-stream work stealing
    steal_quantum: Optional[int] = None   # tiles per dispatch batch
    feedback_alpha: float = 0.35          # EWMA smoothing factor
    feedback_state: Optional[dict] = None  # export_feedback_state() of a
                                           # previous process: warm-starts
                                           # the EWMA + geometry models
    # ---- tile-geometry autotuning (DESIGN.md §Autotuning) ----
    autotune_tiles: bool = False          # warmup sweeps the lattice and
                                          # pins the winning (bm, bn)
    autotune_lattice: Tuple[Tuple[int, int], ...] = GEOMETRY_LATTICE


class ERService:
    """Resident blocked index + two-source query matcher (module docstring).

    ``match(query_titles)`` returns the set of (corpus_index,
    query_index_within_batch) pairs with verified similarity >=
    ``cfg.threshold``. Pass ``mesh=`` for the sharded-index variant
    (corpus row-sharded over ``axis``, queries broadcast).
    """

    def __init__(self, corpus_titles: Sequence[str],
                 config: Optional[ServiceConfig] = None,
                 mesh=None, axis: str = "data"):
        self.cfg = cfg = config if config is not None else ServiceConfig()
        if cfg.strategy not in ("pair_range", "block_split"):
            raise ValueError(f"unknown strategy {cfg.strategy!r}")
        self.mesh = mesh
        self.axis = axis
        self._n_dev = int(mesh.shape[axis]) if mesh is not None else 1
        if cfg.comms not in COMMS_POLICIES:
            raise ValueError(f"unknown comms policy {cfg.comms!r}")
        # Residency row multiple: shard-divisible always; with a comms
        # policy also tile-divisible at EVERY geometry the service can
        # serve (cfg.block_m plus the autotune lattice), so
        # n_loc % bm == 0 holds after any re-pin and per-batch plans
        # never degrade on alignment.
        self._row_mult = self._n_dev
        if mesh is not None and cfg.comms != "flat":
            bms = {int(cfg.block_m)}
            if cfg.autotune_tiles:
                bms |= {int(bm) for bm, _ in cfg.autotune_lattice}
            self._row_mult *= int(np.lcm.reduce(sorted(bms)))
        if cfg.exec_devices > 0 and mesh is not None:
            raise ValueError(
                "supervised execution (exec_devices > 0) drives logical "
                "device shards host-side; it composes with mesh=None only")
        self._n_exec = max(cfg.exec_devices, 1)
        # ONE EWMA model for the service's lifetime: steady-state serving
        # self-tunes — every request's shard timings calibrate the next
        # request's schedule. A previous process's exported state seeds
        # it, so a restarted service schedules from measured rates
        # instead of relearning the fleet from the prior.
        seed_state = cfg.feedback_state or {}
        self.feedback: Optional[EwmaCostModel] = None
        if (cfg.feedback_scheduling or cfg.steal_factor is not None
                or "ewma" in seed_state):
            ewma = seed_state.get("ewma")
            if ewma is not None and int(ewma.get("n_dev", -1)) == self._n_exec:
                self.feedback = EwmaCostModel.from_state(ewma)
            else:
                # No snapshot, or one from a different fleet topology —
                # rates keyed to other devices would mis-calibrate.
                self.feedback = EwmaCostModel(self._n_exec,
                                              alpha=cfg.feedback_alpha)
        self.geometry_feedback = (
            GeometryCostModel.from_state(seed_state["geometry"])
            if "geometry" in seed_state
            else GeometryCostModel(alpha=cfg.feedback_alpha))
        self.tune_report: Optional[TuneReport] = None
        self.fault_injector: Optional[FaultInjector] = None
        self._fail_streak = np.zeros(self._n_exec, np.int64)
        self._breaker_open: Dict[int, float] = {}   # device → eviction time
        # Serializes mutation of host-side shared state (vocab, BDMs,
        # stats, breaker) so overlapping requests — the batcher's planner
        # runs concurrently with its executor — stay correct. Request-
        # scoped state (deadline, reports) lives on _RequestContext, NOT
        # here: an instance field would be clobbered across threads.
        self._host_lock = threading.RLock()
        self._buckets = tuple(sorted(cfg.query_buckets))
        if not self._buckets:
            raise ValueError("query_buckets must be non-empty")
        self._stage1 = cfg.threshold - cfg.filter_margin
        self._titles: List[str] = list(corpus_titles)
        self.n_corpus = n = len(self._titles)

        t0 = time.perf_counter()
        block_ids = np.empty(n, np.int64)
        self._vocab: Dict[str, int] = {}
        for i, t in enumerate(self._titles):  # mirrors prefix_block_ids
            block_ids[i] = self._key_id(t)
        part_ids = np.minimum(
            np.arange(n, dtype=np.int64) * cfg.m // max(n, 1), cfg.m - 1)
        keyed_idx = np.flatnonzero(block_ids >= 0)
        self._null_idx = np.flatnonzero(block_ids < 0)

        codes, lens, feats = featurize(self._titles, cfg)
        self._codes, self._lens = codes, lens

        # ---- Job 1 once: BDM + blocked layout, then stay resident ----
        kb, kp = block_ids[keyed_idx], part_ids[keyed_idx]
        self._bdm = compute_bdm(kb, kp, len(self._vocab), cfg.m)
        eidx = entity_indices(kb, kp, self._bdm)
        perm, _ = blocked_layout(kb, eidx, self._bdm.sum(axis=1))
        self._to_global = keyed_idx[perm]
        self._k_codes = codes[self._to_global]
        self._k_lens = lens[self._to_global]
        self._n_codes = codes[self._null_idx]
        self._n_lens = lens[self._null_idx]

        # Resident device-side feature matrices, one per job kind.
        self._feats_keyed = self._resident(feats[self._to_global])
        self._feats_all = self._resident(feats)
        self._feats_null = self._resident(feats[self._null_idx])
        self.ingest_seconds = time.perf_counter() - t0

        # Cumulative query-side BDM (1 traffic partition) — the running
        # skew statistics a re-balancer would replan from.
        self._traffic_bdm = np.zeros((len(self._vocab), 1), np.int64)
        self.stats: Dict = {"batches": 0, "queries": 0, "planned_pairs": 0,
                            "matches": 0, "seconds": 0.0,
                            "bucket_hits": {b: 0 for b in self._buckets},
                            "retries": 0, "recovered_tiles": 0,
                            "degraded": 0, "breaker_evictions": 0,
                            "breaker_readmissions": 0,
                            "steals": 0, "stolen_tiles": 0}

        # The served tile geometry: cfg.block_m/n until the autotuning
        # warmup pins a lattice winner. Static kernel args everywhere,
        # so each geometry is one compile-cache family.
        self._block_m = cfg.block_m
        self._block_n = cfg.block_n
        self._dist_scorer = None
        self._comms_scorer = None
        self._pin_group = (default_group(self._n_dev)
                           if cfg.comms == "hierarchical" else 1)
        self._build_dist_scorer()

    def _build_dist_scorer(self):
        """(Re)build the mesh cross-mode scorer at the current pinned
        geometry. ONE jitted scorer per geometry for the service's
        lifetime — jit caches by function identity, so a per-batch
        closure would retrace every call (the recompile-guard failure
        mode). Called at construction and on an autotune re-pin (at most
        |lattice| times, all during warmup). Compiled backends get the
        compact scorer: packed-slot decode, no host ``np.nonzero``."""
        if self.mesh is None:
            return
        cfg = self.cfg
        rimpl = _resolve_impl(cfg.kernel_impl)
        self._dist_scorer = make_scorer(
            self.mesh, self.axis, mode="cross", threshold=self._stage1,
            block_m=self._block_m, block_n=self._block_n, impl=rimpl,
            compact=_compact_on_device(rimpl),
            capacity=cfg.compact_capacity)
        if cfg.comms != "flat":
            # The pinned comms scorer. Hop counts are compile-time
            # constants, and for cross-mode jobs ZERO hops is exact, not
            # a guess: every catalog tile's a-rows sit inside one
            # bm-aligned block, and residency padding keeps n_loc a
            # multiple of every served bm, so a locality-placed tile
            # never reads outside its own strip. Ring therefore gathers
            # nothing; hierarchical still assembles its group panel
            # (g − 1 intra hops) with zero inter-group hops. Plans whose
            # alignment gates fail degrade to the flat scorer above.
            self._comms_scorer = make_scorer(
                self.mesh, self.axis, mode="cross", threshold=self._stage1,
                block_m=self._block_m, block_n=self._block_n, impl=rimpl,
                compact=_compact_on_device(rimpl),
                capacity=cfg.compact_capacity, comms=cfg.comms,
                hops=0, group=self._pin_group, inter_hops=0)

    def _set_geometry(self, block_m: int, block_n: int):
        """Pin a served tile geometry (autotune warmup only)."""
        if (block_m, block_n) == (self._block_m, self._block_n):
            return
        self._block_m = int(block_m)
        self._block_n = int(block_n)
        self._build_dist_scorer()

    @property
    def tile_geometry(self) -> Tuple[int, int]:
        """The (block_m, block_n) the service currently serves at."""
        return (self._block_m, self._block_n)

    # ------------------------------------------------------------------
    # Blocking-key vocabulary (persistent across corpus and all batches)
    # ------------------------------------------------------------------

    def _key_id(self, title: str) -> int:
        key = prefix_key(title, self.cfg.prefix_len)  # THE batch key rule
        if key is None:
            return -1
        if key not in self._vocab:
            self._vocab[key] = len(self._vocab)
        return self._vocab[key]

    def _query_block_ids(self, titles: Sequence[str],
                         record: bool = True) -> np.ndarray:
        ids = np.asarray([self._key_id(t) for t in titles], np.int64)
        b_now = len(self._vocab)
        if b_now > self._bdm.shape[0]:
            # Never-seen blocks: grow the resident corpus BDM with zero
            # rows (appended, so the blocked layout is untouched).
            self._bdm = update_bdm(self._bdm, np.zeros(0, np.int64),
                                   np.zeros(0, np.int64), num_blocks=b_now)
        # Warmup's synthetic batches (record=False) must not skew the
        # served-traffic profile — grow the matrix, fold no counts.
        keyed = ids[ids >= 0] if record else np.zeros(0, np.int64)
        self._traffic_bdm = update_bdm(
            self._traffic_bdm, keyed, np.zeros(keyed.size, np.int64),
            num_blocks=b_now)
        return ids

    # ------------------------------------------------------------------
    # Residency and fixed-shape scoring
    # ------------------------------------------------------------------

    def _resident(self, feats: np.ndarray):
        """Move a feature matrix onto the device(s): row-sharded over the
        mesh axis (zero-padded to a shard-divisible row count — tiles'
        validity windows never reach the padding) or a plain device array
        on one device. Empty matrices stay None (their job is skipped)."""
        if feats.shape[0] == 0:
            return None
        if self.mesh is None:
            return jnp.asarray(feats)
        pad = (-feats.shape[0]) % self._row_mult
        if pad:
            feats = np.concatenate(
                [feats, np.zeros((pad, feats.shape[1]), feats.dtype)], axis=0)
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(feats, NamedSharding(self.mesh, P(self.axis)))

    def _bucket(self, nq: int) -> int:
        for b in self._buckets:
            if b >= nq:
                return b
        raise AssertionError("oversized batches are split before bucketing")

    def _bucket_buffer(self, feats: np.ndarray, bucket: int) -> np.ndarray:
        buf = np.zeros((bucket, self.cfg.feature_dim), np.float32)
        buf[:feats.shape[0]] = feats
        return buf

    def _score(self, feats_a, catalog, q_buf: np.ndarray,
               ctx: _RequestContext, comms_plan=None):
        """Stage 1 with fixed shapes: the catalog is pre-padded to a
        tile_chunk multiple, the query buffer to a bucket size, so every
        kernel launch hits a warmed compile-cache entry. Tiles route to
        devices through the compiler's cost-LPT schedule (host-side
        numpy — no effect on the zero-recompile contract); a resolved
        ``comms_plan`` overrides that with its locality placement and
        swaps in the pinned ring/hierarchical scorer (still one jitted
        function — zero recompiles hold). With supervision enabled
        (``cfg.exec_devices`` or an installed fault injector), stage 1
        runs through :func:`execute_supervised` instead — per-shard
        completion records, tile-granular recovery, graceful
        degradation."""
        cfg = self.cfg
        catalog = pad_catalog(catalog, cfg.tile_chunk)
        if self._use_supervisor:
            return self._score_supervised(feats_a, catalog, q_buf, ctx)
        use_comms = (comms_plan is not None
                     and comms_plan.policy != "flat"
                     and self._comms_scorer is not None)
        # Scheduling places tiles on devices — a single-host service has
        # nowhere to place them, so skip the per-batch host work.
        sched = (schedule_tiles(catalog, n_dev=self._n_dev,
                                policy=cfg.schedule_policy,
                                comms_plan=comms_plan if use_comms else None)
                 if self.mesh is not None else None)
        return execute(
            catalog, feats_a, jnp.asarray(q_buf),
            threshold=self._stage1, impl=cfg.kernel_impl,
            mesh=self.mesh, axis=self.axis, schedule=sched,
            scorer=self._comms_scorer if use_comms else self._dist_scorer,
            comms_plan=comms_plan if use_comms else None,
            chunk_tiles=cfg.tile_chunk,
            fixed_chunks=self.mesh is not None,
            compact_capacity=cfg.compact_capacity)

    # ------------------------------------------------------------------
    # Fault-tolerant execution: supervisor + circuit breaker
    # ------------------------------------------------------------------

    @property
    def _use_supervisor(self) -> bool:
        return self.cfg.exec_devices > 0 or self.fault_injector is not None

    def set_fault_injector(self, injector: Optional[FaultInjector]):
        """Install (or clear) a chaos :class:`FaultInjector` — every
        supervised shard call and breaker probe flows through it. Install
        AFTER :meth:`warmup` so warmup traffic doesn't consume script
        events."""
        self.fault_injector = injector

    def _exec_mask(self) -> np.ndarray:
        """Healthy mask over the logical execution devices: everything
        minus the breaker-evicted set."""
        mask = np.ones(self._n_exec, bool)
        for d in self._breaker_open:
            mask[d] = False
        return mask

    def _probe_evicted(self):
        """Re-admission path: once an evicted device's cooldown elapses,
        probe it (one injector shard call — a trivially cheap health RPC
        in a real deployment). Probe success re-admits the device and
        resets its failure streak; failure restarts the cooldown."""
        with self._host_lock:
            now = time.monotonic()
            for d, opened in list(self._breaker_open.items()):
                if now - opened < self.cfg.breaker_cooldown_s:
                    continue
                ok = True
                if self.fault_injector is not None:
                    try:
                        self.fault_injector.shard_call(d)
                    except (DeviceKilledError, TransientScorerError):
                        ok = False
                if ok:
                    del self._breaker_open[d]
                    self._fail_streak[d] = 0
                    if self.feedback is not None:
                        # The EWMA rates this device accumulated while it
                        # straggled describe the device that got EVICTED,
                        # not the one that just passed a health probe —
                        # keeping them would under-schedule the recovered
                        # device indefinitely. Forget them; the next
                        # accepted shard call re-calibrates from the
                        # global rate.
                        self.feedback.reset_device(d)
                    self.stats["breaker_readmissions"] += 1
                else:
                    self._breaker_open[d] = now

    def _update_breaker(self, report: SupervisedReport):
        """Fold a job's shard records into the per-device failure
        streaks; devices at ``breaker_threshold`` consecutive failures
        are evicted until a probe succeeds."""
        with self._host_lock:
            now = time.monotonic()
            for rec in report.records:
                if rec.status == "ok":
                    self._fail_streak[rec.device] = 0
                else:
                    self._fail_streak[rec.device] += 1
                    if (self._fail_streak[rec.device]
                            >= self.cfg.breaker_threshold
                            and rec.device not in self._breaker_open):
                        self._breaker_open[rec.device] = now
                        self.stats["breaker_evictions"] += 1

    def _retry_after(self) -> float:
        """Seconds until the LAST evicted device becomes probeable — a
        client that waits this long is guaranteed the next request
        probes every evicted device, instead of racing the longest
        cooldown and landing back here. Clamped to the cooldown span
        (the remaining time can never legitimately exceed it)."""
        if not self._breaker_open:
            return max(self.cfg.backoff_s, 1e-3)
        now = time.monotonic()
        rem = max(self.cfg.breaker_cooldown_s - (now - t)
                  for t in self._breaker_open.values())
        return min(max(rem, 1e-3), max(self.cfg.breaker_cooldown_s, 1e-3))

    def _score_supervised(self, feats_a, catalog, q_buf: np.ndarray,
                          ctx: _RequestContext):
        """Stage 1 through the fault-tolerant supervisor on
        ``cfg.exec_devices`` logical shards. Collects the report on the
        request context for the coverage aggregation and feeds the
        breaker. The wall budget is whatever remains of the REQUEST's
        deadline — armed once at the outer ``match`` entry, so an
        oversized request's later slices see a shrinking budget instead
        of each re-arming a fresh one."""
        cfg = self.cfg
        self._probe_evicted()
        healthy = self._exec_mask()
        if not healthy.any():
            raise ServiceUnavailable(
                "all execution devices are circuit-broken",
                retry_after_s=self._retry_after())
        remaining = None
        if ctx.deadline_at is not None:
            remaining = max(ctx.deadline_at - time.perf_counter(), 0.0)
        try:
            ra, rb, report = execute_supervised(
                catalog, feats_a, jnp.asarray(q_buf),
                threshold=self._stage1, n_dev=self._n_exec,
                healthy=healthy, impl=cfg.kernel_impl,
                chunk_tiles=cfg.tile_chunk, policy=cfg.schedule_policy,
                injector=self.fault_injector,
                shard_deadline=cfg.shard_deadline_s, deadline=remaining,
                max_retries=cfg.max_retries, backoff=cfg.backoff_s,
                backoff_factor=cfg.backoff_factor,
                partial=cfg.partial_results, feedback=self.feedback,
                steal_factor=cfg.steal_factor,
                steal_quantum=cfg.steal_quantum,
                compact_capacity=cfg.compact_capacity)
        except NoHealthyDevicesError as e:
            # Only reachable with partial_results=False: every device
            # died mid-job. Surface retry-after instead of a traceback.
            raise ServiceUnavailable(
                str(e), retry_after_s=self._retry_after()) from e
        self._update_breaker(report)
        ctx.reports.append(report)
        return ra, rb

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------

    def _new_request_ctx(self) -> _RequestContext:
        """Arm one request's deadline (ONCE — slices share the budget)
        and its report accumulator."""
        deadline_at = (time.perf_counter() + self.cfg.request_deadline_s
                       if self.cfg.request_deadline_s is not None
                       else None)
        return _RequestContext(deadline_at=deadline_at)

    def match(self, query_titles: Sequence[str],
              _record: bool = True) -> "MatchResponse":
        """Match a query micro-batch against the resident corpus.

        Returns a :class:`MatchResponse` — a set of (corpus_index,
        query_index_within_batch) pairs with exact verified similarity
        >= cfg.threshold, by construction equal to a one-shot
        ``run_er(corpus ++ batch)`` restricted to cross pairs — plus
        degradation metadata (``coverage``, ``attempts``,
        ``recovered_tiles``, ``degraded``). Batches larger than the top
        bucket are served in top-bucket slices.

        With supervision enabled, a per-request deadline
        (``cfg.request_deadline_s``) bounds recovery — armed once for
        the whole request, so an oversized batch's slices spend ONE
        shared budget; on exhaustion the response carries the survivors
        found so far with ``coverage < 1`` (``cfg.partial_results``)
        instead of failing. :class:`ServiceUnavailable` (with
        ``retry_after_s``) is raised only when every execution device is
        circuit-broken.

        Thread-safe: concurrent calls see exactly the sequential match
        sets (request state is per-call, host-side index mutation is
        locked). For throughput under concurrency use
        :class:`~.batcher.ERBatcher`, which coalesces submitters into
        super-batches instead of serializing them.
        """
        query_titles = list(query_titles)
        nq = len(query_titles)
        if nq == 0 or self.n_corpus == 0:
            return MatchResponse()
        ctx = self._new_request_ctx()
        cap = self._buckets[-1]
        if nq <= cap:
            return self._match_slice(query_titles, ctx, _record)
        out = MatchResponse()
        for lo in range(0, nq, cap):
            part = self._match_slice(query_titles[lo:lo + cap], ctx,
                                     _record)
            for a, b in part:
                out.add((a, b + lo))
            out.attempts = max(out.attempts, part.attempts)
            out.recovered_tiles += part.recovered_tiles
            out.planned_cost += part.planned_cost
            out.scored_cost += part.scored_cost
            out.steals += part.steals
            out.stolen_tiles += part.stolen_tiles
            out.degraded = out.degraded or part.degraded
        return out

    def _match_slice(self, titles: List[str], ctx: _RequestContext,
                     record: bool) -> "MatchResponse":
        return self._execute_batch(self._plan_batch(titles, ctx, record),
                                   ctx)

    def _plan_batch(self, titles: List[str], ctx: _RequestContext,
                    record: bool = True) -> _PlannedBatch:
        """Host-side half of one ≤ top-bucket batch: featurize, fold the
        batch into the vocab/BDM, plan and lower every job to padded
        fixed-shape catalogs. Everything that touches mutable service
        state happens here under the host lock; the returned
        :class:`_PlannedBatch` is self-contained, so ``_execute_batch``
        can run it on another thread while the next batch plans."""
        t0 = time.perf_counter()
        cfg = self.cfg
        nq = len(titles)
        bucket = self._bucket(nq)
        codes, lens, feats = featurize(titles, cfg)
        jobs: List[_PlannedJob] = []
        planned = 0
        with self._host_lock:
            qb = self._query_block_ids(titles, record=record)

            # ---- keyed queries × same-block corpus (two-source R×S) ----
            keyed_q = np.flatnonzero(qb >= 0)
            if keyed_q.size and self._feats_keyed is not None:
                qkb = qb[keyed_q]
                order = np.argsort(qkb, kind="stable")
                q_rows = keyed_q[order]        # blocked S layout → batch idx
                bdm_s = np.bincount(
                    qkb,
                    minlength=self._bdm.shape[0]).astype(np.int64)[:, None]
                bdm2 = TwoSourceBDM(bdm_r=self._bdm, bdm_s=bdm_s)
                planner = (plan_block_split_2src
                           if cfg.strategy == "block_split"
                           else plan_pair_range_2src)
                plan = planner(bdm2, cfg.r)
                planned += plan.total_pairs
                cat = lower(plan_to_job(plan), self._block_m, self._block_n)
                cplan = None
                if self._comms_scorer is not None:
                    # Plan on the chunk-padded catalog so the locality
                    # placement covers every tile the executor will see
                    # (pad_catalog in _score is then a no-op). Pinned at
                    # zero hops — see _build_dist_scorer; a degraded
                    # plan routes the job to the flat scorer.
                    cat = pad_catalog(cat, cfg.tile_chunk)
                    cplan = plan_comms(
                        cat, int(self._feats_keyed.shape[0]), self._n_dev,
                        policy=cfg.comms, feature_dim=cfg.feature_dim,
                        self_join=False,
                        group=(self._pin_group
                               if cfg.comms == "hierarchical" else None),
                        pin_hops=0, pin_inter_hops=0)
                jobs.append(_PlannedJob(
                    feats_a=self._feats_keyed,
                    catalog=cat,
                    q_buf=self._bucket_buffer(feats[q_rows], bucket),
                    codes_a=self._k_codes, lens_a=self._k_lens,
                    codes_b=codes[q_rows], lens_b=lens[q_rows],
                    map_a=self._to_global, map_b=q_rows,
                    comms_plan=cplan))

            # ---- match_⊥, cross-restricted: null queries × corpus ----
            null_q = np.flatnonzero(qb < 0)
            if cfg.match_missing_keys and null_q.size:
                cat = lower(cross_job(self.n_corpus, int(null_q.size),
                                      cfg.r), self._block_m, self._block_n)
                planned += cat.total_pairs
                jobs.append(_PlannedJob(
                    feats_a=self._feats_all, catalog=cat,
                    q_buf=self._bucket_buffer(feats[null_q], bucket),
                    codes_a=self._codes, lens_a=self._lens,
                    codes_b=codes[null_q], lens_b=lens[null_q],
                    map_a=None, map_b=null_q))

            # ---- ... and null-key corpus entities × the keyed queries
            # (match_⊥(R0, S−S0): null × null pairs are already covered
            # by the null-query job above) ----
            if cfg.match_missing_keys and self._feats_null is not None \
                    and keyed_q.size:
                cat = lower(cross_job(int(self._null_idx.size),
                                      int(keyed_q.size), cfg.r),
                            self._block_m, self._block_n)
                planned += cat.total_pairs
                jobs.append(_PlannedJob(
                    feats_a=self._feats_null, catalog=cat,
                    q_buf=self._bucket_buffer(feats[keyed_q], bucket),
                    codes_a=self._n_codes, lens_a=self._n_lens,
                    codes_b=codes[keyed_q], lens_b=lens[keyed_q],
                    map_a=self._null_idx, map_b=keyed_q))
        return _PlannedBatch(nq=nq, bucket=bucket, t0=t0, record=record,
                             planned=int(planned), jobs=jobs)

    def _execute_batch(self, pb: _PlannedBatch,
                       ctx: _RequestContext) -> "MatchResponse":
        """Device-side half: run each planned job's stage 1 + exact
        stage 2, demap survivors to (corpus_index, batch_index). Holds
        no host lock while kernels run — the batcher overlaps the next
        batch's ``_plan_batch`` with this."""
        cfg = self.cfg
        matches = MatchResponse()
        n_reports = len(ctx.reports)
        for job in pb.jobs:
            ca, cb = self._score(job.feats_a, job.catalog, job.q_buf, ctx,
                                 comms_plan=job.comms_plan)
            ha, hb = verify_pairs(job.codes_a, job.lens_a,
                                  job.codes_b, job.lens_b,
                                  ca, cb, cfg.threshold)
            if job.map_a is None:
                matches.update(
                    (int(a), int(job.map_b[b])) for a, b in zip(ha, hb))
            else:
                matches.update(
                    (int(job.map_a[a]), int(job.map_b[b]))
                    for a, b in zip(ha, hb))
        for report in ctx.reports[n_reports:]:
            matches._fold(report)
        if pb.record:
            with self._host_lock:
                s = self.stats
                s["batches"] += 1
                s["queries"] += pb.nq
                s["planned_pairs"] += pb.planned
                s["matches"] += len(matches)
                s["seconds"] += time.perf_counter() - pb.t0
                s["bucket_hits"][pb.bucket] += 1
                s["retries"] += max(matches.attempts - 1, 0)
                s["recovered_tiles"] += matches.recovered_tiles
                s["degraded"] += int(matches.degraded)
                s["steals"] += matches.steals
                s["stolen_tiles"] += matches.stolen_tiles
        return matches

    def warmup(self) -> int:
        """Compile every steady-state shape before traffic arrives: serve
        one synthetic batch per bucket, built from recycled corpus titles
        (guaranteed stage-1 survivors, so the stage-2 verifier compiles
        too) with one empty title appended to hit the null-key cross
        jobs. Warmup batches are excluded from ``stats``.

        With ``cfg.autotune_tiles`` the top-bucket batch first sweeps the
        geometry lattice (compiling ≤ |lattice| kernel variants, each
        measured once into the geometry EWMA) and pins the winner; every
        bucket then warms at the pinned geometry, so steady-state
        serving still triggers ZERO new compilations. A restarted
        service whose ``cfg.feedback_state`` already carries measured
        lattice rates skips the sweep and pins directly."""
        if self.n_corpus == 0:
            return 0
        reps = -(-self._buckets[-1] // self.n_corpus)
        pool = self._titles * reps
        if self.cfg.autotune_tiles:
            self._autotune_warmup(pool)
        for bucket in self._buckets:
            qs = pool[:bucket]
            if self.cfg.match_missing_keys and qs:
                qs = qs[:-1] + [""]
            self.match(qs, _record=False)
        return len(self._buckets)

    def _tune_job(self, titles: List[str]):
        """The keyed two-source MatchJob a batch of ``titles`` would
        plan — the representative job the autotuner scores. Mirrors the
        keyed branch of :meth:`_plan_batch` without lowering."""
        cfg = self.cfg
        with self._host_lock:
            qb = self._query_block_ids(titles, record=False)
        keyed = qb[qb >= 0]
        if keyed.size == 0:
            return None
        bdm_s = np.bincount(
            keyed, minlength=self._bdm.shape[0]).astype(np.int64)[:, None]
        bdm2 = TwoSourceBDM(bdm_r=self._bdm, bdm_s=bdm_s)
        planner = (plan_block_split_2src if cfg.strategy == "block_split"
                   else plan_pair_range_2src)
        return plan_to_job(planner(bdm2, cfg.r))

    def _autotune_warmup(self, pool: List[str]):
        """Sweep the lattice on the top-bucket synthetic batch, fold each
        candidate's wall time into the geometry EWMA, pin the winner.
        Skips straight to pinning when the seeded geometry model already
        measured a lattice candidate (restart warm start)."""
        cfg = self.cfg
        qs = pool[:self._buckets[-1]]
        job = self._tune_job(qs)
        if job is None or job.total_pairs == 0:
            return
        kwargs = dict(lattice=cfg.autotune_lattice, d=cfg.feature_dim,
                      capacity=cfg.compact_capacity or 0,
                      feedback=self.geometry_feedback)
        if self.geometry_feedback.best(cfg.autotune_lattice) is None:
            report = autotune(job, **kwargs)
            for score in report.scores:
                self._set_geometry(score.block_m, score.block_n)
                t0 = time.perf_counter()
                self.match(qs, _record=False)
                # live_pairs: the keyed job's exact planned pairs — the
                # geometry-invariant denominator that makes measured
                # rates directly comparable across candidates.
                self.geometry_feedback.observe(
                    score.geometry, max(job.total_pairs, 1),
                    time.perf_counter() - t0)
        self.tune_report = autotune(job, **kwargs)
        self._set_geometry(self.tune_report.block_m,
                           self.tune_report.block_n)

    def export_feedback_state(self) -> dict:
        """Snapshot every learned model (device/class EWMA rates, the
        geometry EWMA, the pinned geometry) as one JSON-able dict. Hand
        it to a new process as ``ServiceConfig.feedback_state`` and the
        restarted service schedules — and autotunes — from measurements
        instead of cold priors."""
        state: Dict = {"geometry_pinned": [self._block_m, self._block_n]}
        if self.feedback is not None:
            state["ewma"] = self.feedback.to_state()
        state["geometry"] = self.geometry_feedback.to_state()
        return state

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def bdm(self) -> np.ndarray:
        """Host-side corpus BDM (b × m) — grows rows as queries reveal
        never-seen blocks."""
        return self._bdm

    @property
    def traffic_bdm(self) -> np.ndarray:
        """Cumulative query-side block counts (b × 1): the skew profile
        of served traffic, folded in with :func:`core.bdm.update_bdm`."""
        return self._traffic_bdm
