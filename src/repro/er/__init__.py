"""Entity-resolution substrate: featurization, blocking, similarity,
datasets, the end-to-end pipeline (paper Fig. 2), the unified match-job
compiler (plan → catalog → schedule → execute) and the shard_map
distributed runtime."""
from .blocking import (  # noqa: F401
    dense_block_ids,
    exponential_block_ids,
    prefix_block_ids,
    sn_sort_keys,
    sn_sort_order,
)
from .datasets import Dataset, make_products, make_publications  # noqa: F401
from .encode import encode_titles, ngram_features  # noqa: F401
from .compiler import (  # noqa: F401
    DeviceKilledError,
    EwmaCostModel,
    FaultEvent,
    FaultInjector,
    FaultScript,
    MatchJob,
    NoHealthyDevicesError,
    RecoveryFailedError,
    Schedule,
    SupervisedReport,
    TileCatalog,
    TransientScorerError,
    cross_job,
    execute,
    execute_supervised,
    lower,
    match_catalog,
    plan_to_job,
    schedule_tiles,
    score_catalog,
    tile_costs,
    verify_pairs,
)
from .executor import build_catalog  # noqa: F401
from .pipeline import ERConfig, ERResult, cross_restrict, featurize, run_er  # noqa: F401
from .service import (  # noqa: F401
    ERService,
    MatchResponse,
    ServiceConfig,
    ServiceUnavailable,
    compile_counter,
)
from .batcher import AdmissionError, ERBatcher  # noqa: F401
from .similarity import (  # noqa: F401
    cosine_scores,
    edit_distance,
    edit_similarity,
    two_stage_match,
)
