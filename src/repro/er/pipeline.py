"""End-to-end ER pipeline — the paper's Fig. 2 workflow on one host.

Job 1: blocking keys + block distribution matrix (BDM) — or, for
Sorted Neighborhood, the sort pass (no BDM: the band's pair count is a
pure function of (n, w), so there is no block skew to measure).

Job 2: strategy plan + reduce-phase matching (two-stage cosine-filter →
edit-distance verify), through ONE path for every strategy — the
unified match-job compiler (``er/compiler``):

    plan → plan_to_job → lower → schedule_tiles → execute → verify

The plan lowers to the MatchJob IR, tiles into an MXU catalog, the
cost-LPT scheduler places tiles by their exact live-pair counts
(``ERConfig.schedule_policy``; the reported imbalance lands on
``ERResult.schedule``), and the fused kernel scores the catalog.
``ERConfig.executor = "reference"`` keeps the original per-reducer
numpy loop (materialized pair lists + chunked ``np.einsum``) as the
parity oracle and the before/after benchmark baseline.

Entities without blocking keys (block id −1) follow the paper's
decomposition: match_B(R,R) over the keyed subset ∪ match_⊥(R, R_∅) via a
two-source cartesian job (§III, Appendix I preamble). SN has no match_⊥
job — every entity has a sort key.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core import (
    blocked_layout,
    compute_bdm,
    entity_indices,
    plan_basic,
    plan_block_split,
    plan_pair_range,
    pairs_of_range,
)
from ..core.basic import BasicPlan
from ..core.block_split import BlockSplitPlan
from ..core.pair_range import PairRangePlan, map_output_size as pair_range_map_output_size
from ..core.sorted_neighborhood import (
    SortedNeighborhoodPlan,
    map_output_size as sn_map_output_size,
    pairs_of_band_range,
    plan_sorted_neighborhood,
)
from ..core.two_source import TwoSourceBDM, plan_pair_range_2src, pairs_of_range_2src
from .blocking import prefix_block_ids, sn_sort_order
from .encode import encode_titles, ngram_features
from .compiler import (apply_schedule, autotune, cross_job,
                       enumerate_task_pairs, execute_supervised, lower,
                       match_catalog, plan_to_job, schedule_tiles,
                       verify_pairs)

__all__ = ["ERConfig", "ERResult", "run_er", "featurize", "cross_restrict"]

_CHUNK = 65_536


def featurize(titles: Sequence[str], cfg) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared featurization for the batch pipeline and the resident service:
    (codes, lens) for the exact stage-2 verifier plus the hashed n-gram
    filter features. ``cfg`` needs ``max_len`` and ``feature_dim``
    (ERConfig and ServiceConfig both qualify)."""
    codes, lens = encode_titles(titles, max_len=cfg.max_len)
    feats = ngram_features(codes, dim=cfg.feature_dim, lengths=lens)
    return codes, lens, feats


def cross_restrict(matches: Set[Tuple[int, int]],
                   n_left: int) -> Set[Tuple[int, int]]:
    """Restrict a ``run_er`` match set over ``left ++ right`` to cross
    pairs, re-based as (left_idx, right_local_idx) — exactly what an
    ``ERService`` holding ``left`` resident must return for queries
    ``right`` (the streaming ≡ batch equivalence oracle)."""
    return {(a, b - n_left) for a, b in matches if a < n_left <= b}


@dataclass
class ERConfig:
    strategy: str = "pair_range"       # basic | block_split | pair_range
                                       # | sorted_neighborhood
    r: int = 32                        # reduce tasks
    m: int = 8                         # map tasks / input partitions
    threshold: float = 0.8
    prefix_len: int = 3
    window: int = 10                   # SN sliding-window size w
    feature_dim: int = 256
    max_len: int = 64
    filter_margin: float = 0.25
    match_missing_keys: bool = True
    executor: str = "catalog"          # catalog | reference
    block_m: int = 128                 # catalog tile rows (MXU-aligned)
    block_n: int = 128                 # catalog tile cols
    tune_tiles: bool = False           # pick (block_m, block_n) per job
                                       # via compiler.autotune (catalog
                                       # executor; overrides block_m/n)
    kernel_impl: str = "auto"          # auto | pallas | interpret | xla
    schedule_policy: str = "cost_lpt"  # cost_lpt | round_robin
    comms: str = "flat"                # flat | ring | hierarchical —
                                       # the data-axis gather policy when
                                       # run_er is given a mesh (plans
                                       # that miss the ring preconditions
                                       # degrade to flat, reported on
                                       # ERResult.extra["comms_fallback"])
    # ---- fault-tolerant execution (catalog executor only) ----
    supervised_devices: int = 0        # > 0: stage 1 through the supervisor
                                       # on N logical device shards
    max_retries: int = 3               # recovery rounds per supervised job
    shard_deadline_s: Optional[float] = None   # straggler cutoff per shard
    backoff_s: float = 0.0             # base retry backoff (exponential)
    # ---- runtime feedback (supervised catalog executor only) ----
    steal_factor: Optional[float] = None   # > 0: mid-stream work stealing
    steal_quantum: Optional[int] = None    # tiles per dispatch batch
    # ---- stage-1 survivor compaction (catalog executor) ----
    compact_capacity: Optional[int] = None  # packed slots per tile;
                                            # None = bm·bn (never overflows)


@dataclass
class ERResult:
    matches: Set[Tuple[int, int]]
    total_pairs: int
    reducer_pairs: np.ndarray          # (r,) planned pair loads
    map_output_size: int               # kv-pairs emitted by map (Fig. 12)
    bdm_seconds: float                 # Job-1 time (BDM, or the SN sort)
    reducer_seconds: np.ndarray        # (r,) measured matching time
    extra: Dict = field(default_factory=dict)
    config: Optional[ERConfig] = None  # the (fresh) config this run used
    schedule: Optional[Dict] = None    # compiler Schedule.stats() (catalog
                                       # executor): reducer/device imbalance
    attempts: int = 1                  # supervisor rounds (1 == quiet run)
    recovered_tiles: int = 0           # tiles re-executed after a failure
    coverage: float = 1.0              # live pairs scored / planned
    steals: int = 0                    # work-stealing events (supervised)
    measured_makespan_s: float = 0.0   # supervisor busy-time makespan

    @property
    def makespan_seconds(self) -> float:
        return float(self.reducer_seconds.max()) if self.reducer_seconds.size else 0.0


_VERIFY_CHUNK = 8_192


def _match_pairs_chunked(feats, codes, lens, rows_a, rows_b,
                         threshold, margin) -> Tuple[np.ndarray, np.ndarray]:
    """REFERENCE executor (``ERConfig.executor = "reference"``): filter-
    and-verify over materialized (rows_a, rows_b). Stage 1 is a host
    ``np.einsum`` paired dot; stage 2 the exact verifier. Kept as the
    parity oracle for the compiler path and as the before-side of the
    kernel benchmark — the hot path no longer runs through here."""
    from .similarity import edit_similarity

    n = rows_a.shape[0]
    cand_a, cand_b = [], []
    for lo in range(0, n, _CHUNK):  # stage 1: numpy paired dots
        a = rows_a[lo:lo + _CHUNK]
        b = rows_b[lo:lo + _CHUNK]
        cos = np.einsum("pd,pd->p", feats[a], feats[b])
        sel = np.flatnonzero(cos >= threshold - margin)
        cand_a.append(a[sel])
        cand_b.append(b[sel])
    ca = np.concatenate(cand_a) if cand_a else np.zeros(0, np.int64)
    cb = np.concatenate(cand_b) if cand_b else np.zeros(0, np.int64)

    hit_a, hit_b = [], []
    for lo in range(0, ca.shape[0], _VERIFY_CHUNK):  # stage 2: exact verify
        a = ca[lo:lo + _VERIFY_CHUNK]
        b = cb[lo:lo + _VERIFY_CHUNK]
        pad = _VERIFY_CHUNK - a.shape[0]
        if pad:
            a = np.concatenate([a, np.zeros(pad, a.dtype)])
            b = np.concatenate([b, np.zeros(pad, b.dtype)])
        sim = np.array(edit_similarity(codes[a], lens[a], codes[b], lens[b]))
        if pad:
            sim[_VERIFY_CHUNK - pad:] = 0.0
        sel = np.flatnonzero(sim >= threshold)
        hit_a.append(a[sel])
        hit_b.append(b[sel])
    if not hit_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(hit_a), np.concatenate(hit_b)


def _reference_reducer_rows(plan, r: int) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Materialized per-reducer (rows_a, rows_b) for the reference
    executor — the O(P) path the compiler's catalog replaces. Pair
    enumeration is the compiler's (``enumerate_task_pairs``), so the
    triangular/rect logic exists exactly once in the codebase."""
    rows: List[Tuple[np.ndarray, np.ndarray]] = [
        (np.zeros(0, np.int64), np.zeros(0, np.int64)) for _ in range(r)]

    def add(k, ra, rb):
        pa, pb = rows[k]
        rows[k] = (np.concatenate([pa, ra]), np.concatenate([pb, rb]))

    if isinstance(plan, PairRangePlan):
        for k in range(r):
            _, _, _, ra, rb = pairs_of_range(plan, k)
            rows[k] = (ra, rb)
    elif isinstance(plan, SortedNeighborhoodPlan):
        for k in range(r):
            ra, rb = pairs_of_band_range(plan, k)
            rows[k] = (ra, rb)
    elif isinstance(plan, BlockSplitPlan):
        for t in range(plan.task_block.shape[0]):
            ra, rb = enumerate_task_pairs(
                int(plan.task_a_start[t]), int(plan.task_a_len[t]),
                int(plan.task_b_start[t]), int(plan.task_b_len[t]),
                bool(plan.task_triangular[t]))
            add(int(plan.task_reducer[t]), ra, rb)
    elif isinstance(plan, BasicPlan):
        sizes = plan.block_sizes
        estart = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)[:-1]])
        for k_blk in np.flatnonzero(sizes >= 2):
            ra, rb = enumerate_task_pairs(
                int(estart[k_blk]), int(sizes[k_blk]), 0, 0, True)
            add(int(plan.block_reducer[k_blk]), ra, rb)
    else:
        raise TypeError(f"no reference enumeration for {type(plan).__name__}")
    return rows


def run_er(titles: Sequence[str], config: Optional[ERConfig] = None,
           block_ids: Optional[np.ndarray] = None,
           fault_injector=None, feedback=None,
           mesh=None, axis: str = "data") -> ERResult:
    """Match a single source. ``block_ids`` overrides prefix blocking (used
    by the Fig. 9 skew study; ignored by ``strategy="sorted_neighborhood"``,
    which partitions a sliding window over the sort order, not blocks).

    ``config=None`` builds a fresh default ``ERConfig`` per call (a shared
    mutable default instance would leak mutations across calls); the
    resolved config is returned on ``ERResult.config``.

    With ``cfg.supervised_devices > 0`` (or a ``fault_injector``), the
    catalog executor's stage 1 runs through the fault-tolerant supervisor
    (``compiler.execute_supervised``) on that many logical device shards;
    ``ERResult.attempts`` / ``recovered_tiles`` / ``coverage`` report what
    recovery did. The recovery invariant — the match set equals the
    failure-free run for any injected failure sequence — is the
    supervisor's headline contract (DESIGN.md §Fault tolerance).

    ``feedback`` (an ``EwmaCostModel``, supervised runs only) calibrates
    every supervised schedule by measured shard latency and enables
    ``cfg.steal_factor`` work stealing; pass the same model across calls
    to keep its calibration. With ``cfg.steal_factor`` set and no model
    given, a fresh one is created for the run.

    ``mesh`` runs the main Job-2 catalog on real devices (catalog
    executor only) through ``compiler.execute``: rows shard over
    ``axis``, ``cfg.comms`` picks the gather policy, and — when the mesh
    has a ``model`` axis of size > 1 (``sharding.make_er_mesh``) — the
    feature dimension shards over it with in-scorer psum combination.
    Features are zero-padded to shard/tile-divisible sizes host-side
    (padding rows/columns are never referenced by catalog tiles and
    contribute 0 to every dot). The match_⊥ job is query-batch-sized
    and stays on the host path, as does the reference executor.
    """
    n = len(titles)
    cfg = config if config is not None else ERConfig()
    if cfg.executor not in ("catalog", "reference"):
        raise ValueError(f"unknown executor {cfg.executor!r}")
    supervised = cfg.supervised_devices > 0 or fault_injector is not None
    if supervised and cfg.executor != "catalog":
        raise ValueError("supervised execution requires executor='catalog'")
    if mesh is not None and supervised:
        raise ValueError("supervised execution drives logical shards "
                         "host-side; it cannot also run on a mesh")
    if mesh is not None and cfg.executor != "catalog":
        raise ValueError("mesh execution requires executor='catalog'")
    if supervised and feedback is None and cfg.steal_factor is not None:
        from .compiler import EwmaCostModel
        feedback = EwmaCostModel(max(cfg.supervised_devices, 1))

    # ---- featurize once (shared by both jobs) ----
    codes, lens, feats = featurize(titles, cfg)

    extra: Dict = {}
    null_idx: Optional[np.ndarray] = None

    # ---- Job 1 + plan: the ONLY strategy-aware stage ----
    if cfg.strategy == "sorted_neighborhood":
        # Job 1 is the sort (no BDM — the band's pair count is a pure
        # function of (n, w), so there is no block skew to measure), and
        # every entity has a sort key, so SN has no match_⊥ job.
        t0 = time.perf_counter()
        to_global = sn_sort_order(titles)
        plan = plan_sorted_neighborhood(n, cfg.window, cfg.r)
        bdm_seconds = time.perf_counter() - t0
        map_out = sn_map_output_size(plan)
        extra.update(window=cfg.window, w_eff=plan.w_eff)
    elif cfg.strategy in ("basic", "block_split", "pair_range"):
        if block_ids is None:
            block_ids, _ = prefix_block_ids(titles, k=cfg.prefix_len)
        block_ids = np.asarray(block_ids, np.int64)

        # Input partitions: m contiguous row ranges (HDFS-split analog).
        part_ids = np.minimum(
            np.arange(n, dtype=np.int64) * cfg.m // max(n, 1), cfg.m - 1)

        keyed = block_ids >= 0
        keyed_idx = np.flatnonzero(keyed)
        if (~keyed).any():
            null_idx = np.flatnonzero(~keyed)

        # ---- Job 1: BDM ----
        t0 = time.perf_counter()
        kb = block_ids[keyed_idx]
        kp = part_ids[keyed_idx]
        num_blocks = int(kb.max()) + 1 if kb.size else 0
        bdm = compute_bdm(kb, kp, num_blocks, cfg.m)
        eidx = entity_indices(kb, kp, bdm)
        bdm_seconds = time.perf_counter() - t0

        sizes = bdm.sum(axis=1)
        perm, _ = blocked_layout(kb, eidx, sizes)
        # perm[blocked_row] = row within keyed_idx → global entity ids.
        to_global = keyed_idx[perm]

        if cfg.strategy == "pair_range":
            plan = plan_pair_range(bdm, cfg.r)
            # Closed-form O(r + b) math (core/pair_range.map_output_size)
            # — exact at any scale, so it is ALWAYS computed.
            map_out = pair_range_map_output_size(plan)
        elif cfg.strategy == "block_split":
            plan = plan_block_split(bdm, cfg.r)
            map_out = plan.map_output_size()
        else:
            plan = plan_basic(bdm, cfg.r)
            map_out = plan.map_output_size()
    else:
        raise ValueError(f"unknown strategy {cfg.strategy!r}")

    g_feats = feats[to_global]
    g_codes = codes[to_global]
    g_lens = lens[to_global]
    reducer_pairs = np.asarray(plan.reducer_pairs, np.int64)
    total = int(plan.total_pairs)

    # ---- Job 2: reduce-phase matching (one path for every strategy) ----
    matches: Set[Tuple[int, int]] = set()
    reducer_seconds = np.zeros(cfg.r)
    sched_report: Optional[Dict] = None
    attempts, recovered_tiles = 1, 0
    planned_cost, scored_cost = 0, 0
    steals, measured_makespan = 0, 0.0

    def _supervised_stage1(catalog, feats_a, feats_b=None):
        """Stage 1 through the fault-tolerant supervisor; folds the
        report into the run-level recovery accounting."""
        nonlocal attempts, recovered_tiles, planned_cost, scored_cost, \
            steals, measured_makespan
        ca, cb, rep = execute_supervised(
            catalog, feats_a, feats_b,
            threshold=cfg.threshold - cfg.filter_margin,
            n_dev=max(cfg.supervised_devices, 1), impl=cfg.kernel_impl,
            policy=cfg.schedule_policy, injector=fault_injector,
            shard_deadline=cfg.shard_deadline_s,
            max_retries=cfg.max_retries, backoff=cfg.backoff_s,
            feedback=feedback, steal_factor=cfg.steal_factor,
            steal_quantum=cfg.steal_quantum,
            compact_capacity=cfg.compact_capacity)
        attempts = max(attempts, rep.rounds)
        recovered_tiles += rep.recovered_tiles
        planned_cost += rep.planned_cost
        scored_cost += rep.scored_cost
        steals += rep.steals
        measured_makespan += rep.measured_makespan_s
        return ca, cb

    def _geometry(job) -> Tuple[int, int]:
        """Per-job tile geometry: the occupancy autotuner's pick when
        ``cfg.tune_tiles``, else the configured (block_m, block_n)."""
        if not cfg.tune_tiles:
            return cfg.block_m, cfg.block_n
        rep = autotune(job, d=cfg.feature_dim,
                       capacity=cfg.compact_capacity or 0)
        extra.setdefault("tuned_geometry", {})[
            f"job{len(extra['tuned_geometry'])}"] = rep.geometry
        return rep.geometry

    if cfg.executor == "catalog":
        # The compiler pipeline: lower the plan to MXU tiles, place tiles
        # by exact live-pair cost (LPT), score them all on the kernel,
        # verify compacted survivors. Wall time is attributed to reducers
        # by planned load (the paper's balance metric), since no
        # per-reducer loop exists anymore.
        job = plan_to_job(plan)
        catalog = lower(job, *_geometry(job))
        extra["catalog_tiles"] = catalog.num_tiles
        exec_feats, model_axis, comms_plan = g_feats, None, None
        n_dev = 1
        if mesh is not None:
            n_dev = int(mesh.shape[axis])
            n_model = (int(mesh.shape["model"])
                       if "model" in mesh.axis_names and axis != "model"
                       else 1)
            if n_model > 1:
                model_axis = "model"
            # Zero-pad rows to shard×tile-aligned length and columns to
            # model-divisible width: catalog tiles only reference real
            # rows, and zero feature columns contribute 0 to every dot.
            mult = n_dev * int(np.lcm(catalog.block_m, catalog.block_n))
            rows_p = -(-g_feats.shape[0] // mult) * mult
            cols_p = -(-g_feats.shape[1] // n_model) * n_model
            if (rows_p, cols_p) != g_feats.shape:
                exec_feats = np.zeros((rows_p, cols_p), g_feats.dtype)
                exec_feats[:g_feats.shape[0], :g_feats.shape[1]] = g_feats
            if cfg.comms != "flat":
                from .compiler import plan_comms
                comms_plan = plan_comms(
                    catalog, rows_p, n_dev, policy=cfg.comms,
                    n_model=n_model, feature_dim=cols_p, self_join=True)
                if comms_plan.fallback:
                    extra["comms_fallback"] = comms_plan.fallback
        sched = schedule_tiles(catalog, n_dev=n_dev,
                               policy=cfg.schedule_policy,
                               comms_plan=comms_plan)
        sched_report = sched.stats()
        t0 = time.perf_counter()
        if supervised:
            ca, cb = _supervised_stage1(
                apply_schedule(catalog, sched), g_feats)
            ha, hb = verify_pairs(g_codes, g_lens, g_codes, g_lens,
                                  ca, cb, cfg.threshold)
        else:
            ha, hb = match_catalog(
                apply_schedule(catalog, sched), exec_feats, g_codes, g_lens,
                threshold=cfg.threshold, filter_margin=cfg.filter_margin,
                impl=cfg.kernel_impl, mesh=mesh, axis=axis,
                schedule=sched if mesh is not None else None,
                model_axis=model_axis,
                compact_capacity=cfg.compact_capacity)
        elapsed = time.perf_counter() - t0
        for a, b in zip(to_global[ha], to_global[hb]):
            matches.add((min(int(a), int(b)), max(int(a), int(b))))
        if total:
            reducer_seconds = (elapsed * reducer_pairs.astype(np.float64)
                               / total)
    else:
        for k, (ra, rb) in enumerate(_reference_reducer_rows(plan, cfg.r)):
            if ra.size == 0:
                continue
            t0 = time.perf_counter()
            ha, hb = _match_pairs_chunked(
                g_feats, g_codes, g_lens, ra, rb,
                cfg.threshold, cfg.filter_margin)
            reducer_seconds[k] = time.perf_counter() - t0
            for a, b in zip(to_global[ha], to_global[hb]):
                matches.add((min(int(a), int(b)), max(int(a), int(b))))

    # ---- match_⊥(R, R_∅): entities without blocking key vs everyone ----
    if cfg.match_missing_keys and null_idx is not None and null_idx.size:
        bdm2 = TwoSourceBDM(
            bdm_r=np.full((1, 1), n, np.int64),
            bdm_s=np.full((1, 1), null_idx.size, np.int64))
        plan2 = plan_pair_range_2src(bdm2, cfg.r)
        extra["null_key_pairs"] = plan2.total_pairs
        if cfg.executor == "catalog":
            xjob = cross_job(n, int(null_idx.size), cfg.r)
            cross = lower(xjob, *_geometry(xjob))
            if supervised:
                ca, cb = _supervised_stage1(cross, feats, feats[null_idx])
                ha, hb = verify_pairs(codes, lens, codes[null_idx],
                                      lens[null_idx], ca, cb, cfg.threshold)
            else:
                ha, hb = match_catalog(
                    cross, feats, codes, lens,
                    feats_b=feats[null_idx], codes_b=codes[null_idx],
                    lens_b=lens[null_idx],
                    threshold=cfg.threshold, filter_margin=cfg.filter_margin,
                    impl=cfg.kernel_impl,
                    compact_capacity=cfg.compact_capacity)
            for a, b in zip(ha, null_idx[hb]):
                a, b = int(a), int(b)
                if a != b:
                    matches.add((min(a, b), max(a, b)))
        else:
            for k in range(cfg.r):
                _, _, _, rr, rs = pairs_of_range_2src(plan2, k)
                if rr.size == 0:
                    continue
                ha, hb = _match_pairs_chunked(
                    feats, codes, lens,
                    rr, null_idx[rs], cfg.threshold, cfg.filter_margin)
                for a, b in zip(ha, hb):
                    a, b = int(a), int(b)
                    if a != b:
                        matches.add((min(a, b), max(a, b)))
        total += plan2.total_pairs

    return ERResult(
        matches=matches,
        total_pairs=int(total),
        reducer_pairs=reducer_pairs,
        map_output_size=int(map_out),
        bdm_seconds=bdm_seconds,
        reducer_seconds=reducer_seconds,
        extra=extra,
        config=cfg,
        schedule=sched_report,
        attempts=attempts,
        recovered_tiles=recovered_tiles,
        coverage=(scored_cost / planned_cost if planned_cost else 1.0),
        steals=steals,
        measured_makespan_s=measured_makespan,
    )
