"""Synthetic stand-ins for the paper's evaluation datasets (Fig. 8).

DS1 (~114,000 product descriptions) and DS2 (~1.39M publication records)
are not shipped offline, so we generate corpora whose *blocking
statistics* match Fig. 8's regime under prefix blocking:

  DS1: largest block ≈ 71% of all pairs (a single dominating block);
  DS2: largest block ≈ 4% of entities / 26% of pairs, ~10× more blocks.

(The printed DS1 row — 1,483 blocks, 1.1·10⁵ entities, 3·10⁶ pairs — is
internally inconsistent: Cauchy-Schwarz forces ≥ 4.3·10⁶ pairs for those
block counts. We therefore match the *skew shares*, which drive the
paper's findings, and let block counts float; see EXPERIMENTS.md.)

Construction: block sizes are generated directly (head block = target
entity share; power-law mid tier; geometric tail), the tail exponent is
calibrated by bisection so the head block's share of pairs hits the
target. Each block gets a unique 3-char prefix over [a-z0-9] (36³ key
space), so ``prefix_block_ids(titles, 3)`` recovers exactly this layout —
the generator *is* the paper's "first three letters of the title"
blocking. Ground-truth duplicates are injected by perturbing titles past
position 3 (preserving the block) at edit-similarity ≳ 0.8, so matcher
accuracy is testable alongside throughput.

Deterministic in ``seed``; ``n`` rescales everything for tests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Set, Tuple

import numpy as np

__all__ = ["Dataset", "make_products", "make_publications", "skewed_block_sizes"]

_WORDS = [
    "laptop", "phone", "camera", "monitor", "keyboard", "mouse", "printer",
    "router", "speaker", "headset", "tablet", "charger", "adapter", "cable",
    "drive", "memory", "battery", "case", "stand", "dock", "hub", "lens",
    "pro", "max", "ultra", "mini", "air", "plus", "lite", "neo", "prime",
]


@dataclass
class Dataset:
    """titles + ground-truth duplicate pairs (indices into titles)."""
    name: str
    titles: List[str]
    true_pairs: Set[Tuple[int, int]] = field(default_factory=set)
    prefix_len: int = 3   # blocking-key length that recovers the layout

    @property
    def n(self) -> int:
        return len(self.titles)


def skewed_block_sizes(n: int, head_frac: float, pair_share: float,
                       rng: np.random.Generator) -> np.ndarray:
    """Block sizes: one head block of ``head_frac·n`` entities plus a
    power-law tail with exponent calibrated so the head block holds
    ``pair_share`` of all pairs."""
    head = max(2, int(round(head_frac * n)))
    rest = n - head
    head_pairs = head * (head - 1) // 2

    def tail_sizes(a: float) -> np.ndarray:
        # sizes ∝ k^{-a}, k = 1.., scaled to sum to ``rest``; floor 1,
        # cap at the head size (the head stays the largest block).
        b_guess = max(8, rest // 3)
        w = np.power(np.arange(1, b_guess + 1, dtype=np.float64), -a)
        s = np.maximum(1, np.round(w * (rest / w.sum()))).astype(np.int64)
        s = np.minimum(s, head)
        # trim/extend to hit the exact total
        c = np.cumsum(s)
        cut = int(np.searchsorted(c, rest, side="left")) + 1
        s = s[:cut]
        s[-1] -= int(c[min(cut - 1, len(c) - 1)] - rest)
        if s[-1] <= 0:
            s = s[:-1]
        return s[s > 0]

    # Larger exponent → mass concentrates in the first tail blocks → more
    # tail pairs → lower head share. Bisect a to hit the target share.
    lo_a, hi_a = 0.01, 3.0
    for _ in range(48):
        mid = 0.5 * (lo_a + hi_a)
        s = tail_sizes(mid)
        share = head_pairs / (head_pairs + float((s * (s - 1) // 2).sum()))
        if share > pair_share:
            lo_a = mid       # head too dominant → fatten the tail
        else:
            hi_a = mid
    sizes = np.concatenate([[head], tail_sizes(0.5 * (lo_a + hi_a))])
    assert sizes[0] >= sizes[1:].max(), "head block must stay the largest"
    return sizes.astype(np.int64)


_ALPHABET = "abcdefghijklmnopqrstuvwxyz0123456789"


def _prefixes(count: int) -> Tuple[List[str], int]:
    """``count`` distinct fixed-width prefixes (+ the width used)."""
    width = 3
    while len(_ALPHABET) ** width < count:
        width += 1
    out = []
    for tup in itertools.product(_ALPHABET, repeat=width):
        out.append("".join(tup))
        if len(out) == count:
            return out, width
    raise AssertionError


def _perturb(rng: np.random.Generator, title: str, keep: int = 3) -> str:
    """1-2 char edits after position ``keep`` — preserves the block and
    stays above 0.8 normalized similarity for typical title lengths."""
    s = list(title)
    for _ in range(int(rng.integers(1, 3))):
        op = int(rng.integers(0, 3))
        pos = keep + int(rng.integers(0, max(1, len(s) - keep)))
        ch = _ALPHABET[int(rng.integers(0, 26))]
        if op == 0 and len(s) > 12:
            del s[min(pos, len(s) - 1)]
        elif op == 1:
            s.insert(min(pos, len(s)), ch)
        else:
            s[min(pos, len(s) - 1)] = ch
    return "".join(s)


def _build(name: str, n: int, head_frac: float, pair_share: float,
           seed: int, dup_frac: float) -> Dataset:
    rng = np.random.default_rng(seed)
    base = int(n / (1 + dup_frac))
    sizes = skewed_block_sizes(base, head_frac, pair_share, rng)
    prefixes, width = _prefixes(len(sizes))
    titles: List[str] = []
    for blk, size in enumerate(sizes):
        pre = prefixes[blk]
        w = rng.integers(0, len(_WORDS), (size, 2))
        serial = rng.integers(0, 10_000, size)
        titles.extend(
            f"{pre} {_WORDS[a]} {_WORDS[b]} {v:04d}"
            for a, b, v in zip(w[:, 0], w[:, 1], serial))

    n_dup = int(len(titles) * dup_frac)
    dup_src = rng.choice(len(titles), size=n_dup, replace=False)
    pairs: Set[Tuple[int, int]] = set()
    for src in dup_src:
        titles.append(_perturb(rng, titles[int(src)], keep=width))
        pairs.add((int(src), len(titles) - 1))

    perm = rng.permutation(len(titles))       # arbitrary input order
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    shuffled = [titles[int(i)] for i in perm]
    pairs = {tuple(sorted((int(inv[a]), int(inv[b])))) for a, b in pairs}
    return Dataset(name=name, titles=shuffled, true_pairs=pairs,
                   prefix_len=width)


def make_products(n: int = 114_000, seed: int = 0, dup_frac: float = 0.05) -> Dataset:
    """DS1-like: one block dominates with ~71% of all pairs (Fig. 8)."""
    return _build("DS1-products", n, head_frac=0.018, pair_share=0.71,
                  seed=seed, dup_frac=dup_frac)


def make_publications(n: int = 1_390_000, seed: int = 1, dup_frac: float = 0.03) -> Dataset:
    """DS2-like: largest block ≈ 4% of entities / 26% of pairs (Fig. 8)."""
    return _build("DS2-publications", n, head_frac=0.04, pair_share=0.26,
                  seed=seed, dup_frac=dup_frac)
