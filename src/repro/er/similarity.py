"""Pair similarity: the reduce-phase matcher (paper §VI: edit distance on
titles, match iff similarity ≥ 0.8).

Production path is two-stage (DESIGN.md §2):
  1. cosine over hashed n-gram features — a matmul (MXU / Pallas kernel);
  2. exact normalized edit distance on the survivors — the paper-faithful
     verifier, vectorized over pairs with an anti-diagonal-free DP: each
     DP row update is

        c[j]       = min(prev[j] + 1, prev[j-1] + subst_cost[j])
        new[j]     = min(c[j], min_{k<j}(c[k] + (j - k)))
                   = min(c[j], cummin(c - iota) + iota)

     i.e. the sequential insert chain becomes a parallel cumulative min
     (``lax.associative_scan``), so one title of length L costs L scans of
     O(L) vector work, batched over all pairs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["cosine_scores", "edit_distance", "edit_similarity",
           "two_stage_match", "edit_distance_np"]


def cosine_scores(a, b):
    """(P, d) × (P, d) row-paired cosine scores (features pre-normalized)."""
    return jnp.einsum("pd,pd->p", a, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def edit_distance(a_codes, a_len, b_codes, b_len):
    """Levenshtein distance for each row pair.

    a_codes, b_codes: (P, L) uint8 (0-padded); a_len, b_len: (P,) int32.
    Returns (P,) int32. Padding is excluded by clamping the DP to the true
    lengths at the end (cells beyond a row's length never influence the
    returned cell because we read dp[b_len] after a_len row steps — we
    therefore run all L row steps but freeze rows past a_len).
    """
    P, L = a_codes.shape
    iota = jnp.arange(L + 1, dtype=jnp.int32)
    row0 = jnp.broadcast_to(iota, (P, L + 1))

    def step(prev, i):
        ai = a_codes[:, i][:, None]                       # (P, 1)
        subst = (ai != b_codes).astype(jnp.int32)         # (P, L)
        c_head = prev[:, :1] + 1
        c_tail = jnp.minimum(prev[:, 1:] + 1, prev[:, :-1] + subst)
        c = jnp.concatenate([c_head, c_tail], axis=1)     # (P, L+1)
        pm = jax.lax.associative_scan(jnp.minimum, c - iota, axis=1)
        new = jnp.minimum(c, pm + iota)
        # Freeze rows past this pair's a-length (i >= a_len): keep prev.
        keep = (i < a_len)[:, None]
        return jnp.where(keep, new, prev), None

    dp, _ = jax.lax.scan(step, row0, jnp.arange(L, dtype=jnp.int32))
    return jnp.take_along_axis(dp, b_len[:, None].astype(jnp.int32), axis=1)[:, 0]


def edit_similarity(a_codes, a_len, b_codes, b_len):
    """Normalized similarity 1 − dist / max(len_a, len_b) ∈ [0, 1]."""
    d = edit_distance(a_codes, a_len, b_codes, b_len).astype(jnp.float32)
    mx = jnp.maximum(jnp.maximum(a_len, b_len), 1).astype(jnp.float32)
    return 1.0 - d / mx


def edit_distance_np(a: str, b: str) -> int:
    """Plain O(len_a · len_b) reference used by tests."""
    la, lb = len(a), len(b)
    dp = list(range(lb + 1))
    for i in range(1, la + 1):
        prev = dp[0]
        dp[0] = i
        for j in range(1, lb + 1):
            cur = dp[j]
            dp[j] = min(dp[j] + 1, dp[j - 1] + 1, prev + (a[i - 1] != b[j - 1]))
            prev = cur
    return dp[lb]


def two_stage_match(feats_a, feats_b, codes_a, len_a, codes_b, len_b,
                    threshold: float = 0.8, filter_margin: float = 0.25):
    """Filter-and-verify for row-paired candidates.

    Stage 1 keeps pairs with cosine ≥ threshold − margin (cheap, MXU);
    stage 2 verifies with exact edit similarity ≥ threshold. Cheap pairs
    that fail the filter skip the verifier *mathematically* (their stage-2
    result is masked), though under jit both branches are computed — the
    skipping materializes as tile-level sparsity in the Pallas/bucketed
    executor, not here.

    Returns (match_mask bool (P,), scores float32 (P,)).
    """
    cos = cosine_scores(feats_a, feats_b)
    candidate = cos >= (threshold - filter_margin)
    sim = edit_similarity(codes_a, len_a, codes_b, len_b)
    match = candidate & (sim >= threshold)
    return match, jnp.where(match, sim, 0.0)
