"""Tile-catalog executor: plan → one fused Pallas call (DESIGN.md §Catalog).

Every load-balancing plan (Basic / BlockSplit / PairRange) describes a set
of pairs as geometry over the blocked feature layout: triangular tasks
(whole blocks, sub-blocks k.i), rectangular tasks (cross sub-blocks
k.i×j), and PairRange's corner-cut triangle segments. This module
*compiles* that geometry into a flat catalog of MXU-aligned
(block_m, block_n) tiles — (a_tile, b_tile, validity window, triangular
flag, corner cuts, reducer) per entry, int32 — and scores the whole
catalog with the scalar-prefetch kernel ``kernels.pair_sim.
pair_scores_catalog`` (or its XLA twin on CPU). The paper's >95%-of-
runtime reduce phase thus runs as one kernel launch per survivor-mask
chunk instead of a Python per-reducer loop over materialized pair lists.

Memory: the catalog is O(#tiles) = O(#tasks + planned_pairs / (bm·bn)),
never O(P) host-side pair indices — the previous ``np.triu_indices`` /
``meshgrid`` path materialized 16 bytes per pair. Stage-2 exact
edit-distance verification (``verify_pairs``) runs only on the compacted
stage-1 survivors.

Catalog column layout: see ``kernels.pair_sim`` (NCOLS = 13).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np

from ..core.basic import BasicPlan
from ..core.block_split import BlockSplitPlan
from ..core.pair_range import PairRangePlan, range_block_segments
from ..core.sorted_neighborhood import SortedNeighborhoodPlan, band_range_segment
from ..core.two_source import (BlockSplit2Plan, PairRange2Plan,
                               range_block_segments_2src)
from ..kernels.pair_sim import NCOLS

__all__ = [
    "TileCatalog",
    "catalog_for_basic",
    "catalog_for_block_split",
    "catalog_for_pair_range",
    "catalog_for_sorted_neighborhood",
    "catalog_for_cross",
    "catalog_for_two_source",
    "build_catalog",
    "pad_catalog_tiles",
    "score_catalog",
    "verify_pairs",
    "match_catalog",
    "enumerate_catalog_pairs",
]

# Column indices (mirrors kernels.pair_sim's layout comment).
(A_TILE, B_TILE, R0, R1, C0, C1, TRI, LB_R, LB_C, UB_R, UB_C, BAND,
 RED) = range(NCOLS)

_NO_LB = -1           # rows are >= 0, so row > -1 always holds
_NO_UB = 2 ** 30      # rows are < 2^30, so row < 2^30 always holds


@dataclass(frozen=True)
class TileCatalog:
    """A compiled plan: T MXU tiles covering every planned pair once."""
    tiles: np.ndarray      # (T, NCOLS) int32
    block_m: int
    block_n: int
    n_rows_a: int          # LHS feature-matrix rows the tiles index into
    n_rows_b: int          # RHS rows (== n_rows_a for single-source plans)
    r: int                 # reduce tasks (tiles[:, RED] ∈ [0, r))
    total_pairs: int       # planned pair count (exact, from the plan)

    @property
    def num_tiles(self) -> int:
        return int(self.tiles.shape[0])


def _task_tiles(a0: int, alen: int, b0: int, blen: int, tri: bool,
                reducer: int, bm: int, bn: int,
                lb: Tuple[int, int] = (_NO_LB, _NO_LB),
                ub: Tuple[int, int] = (_NO_UB, _NO_UB),
                band: int = 0) -> np.ndarray:
    """Aligned tiles intersecting one task's [a0, a0+alen) × [b0, b0+blen)
    window. Validity windows/cuts are global-row predicates, so every tile
    of a task carries the same scalars; triangular tasks drop tiles
    entirely on/below the diagonal (no row < col cell), banded tasks
    additionally drop tiles entirely above the col − row < band diagonal —
    the tile set hugs the band instead of filling the bounding rectangle."""
    if alen <= 0 or blen <= 0:
        return np.zeros((0, NCOLS), np.int32)
    ii = np.arange(a0 // bm, -(-(a0 + alen) // bm), dtype=np.int64)
    jj = np.arange(b0 // bn, -(-(b0 + blen) // bn), dtype=np.int64)
    tii, tjj = np.meshgrid(ii, jj, indexing="ij")
    tii, tjj = tii.ravel(), tjj.ravel()
    if tri:
        keep = np.maximum(tii * bm, a0) < np.minimum((tjj + 1) * bn, b0 + blen)
        tii, tjj = tii[keep], tjj[keep]
    if band > 0:
        # Some cell with col − row < band: min over the tile∩window of
        # (col − row) is clipped_col_start − (clipped_row_end − 1).
        keep = (np.maximum(tjj * bn, b0)
                < np.minimum((tii + 1) * bm, a0 + alen) + band - 1)
        tii, tjj = tii[keep], tjj[keep]
    t = np.empty((tii.size, NCOLS), np.int32)
    t[:, A_TILE] = tii
    t[:, B_TILE] = tjj
    t[:, R0] = a0
    t[:, R1] = a0 + alen
    t[:, C0] = b0
    t[:, C1] = b0 + blen
    t[:, TRI] = int(tri)
    t[:, LB_R], t[:, LB_C] = lb
    t[:, UB_R], t[:, UB_C] = ub
    t[:, BAND] = band
    t[:, RED] = reducer
    return t


def _stack(parts, bm, bn, n_rows_a, n_rows_b, r, total) -> TileCatalog:
    tiles = (np.concatenate(parts, axis=0) if parts
             else np.zeros((0, NCOLS), np.int32))
    return TileCatalog(tiles=tiles, block_m=bm, block_n=bn,
                       n_rows_a=n_rows_a, n_rows_b=n_rows_b,
                       r=r, total_pairs=total)


# ---------------------------------------------------------------------------
# Plan compilers
# ---------------------------------------------------------------------------

def catalog_for_basic(plan: BasicPlan, block_m: int = 128,
                      block_n: int = 128) -> TileCatalog:
    """One triangular task per block with >= 1 pair, on its reducer."""
    sizes = plan.block_sizes
    estart = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)[:-1]])
    parts = [
        _task_tiles(int(estart[k]), int(sizes[k]),
                    int(estart[k]), int(sizes[k]), True,
                    int(plan.block_reducer[k]), block_m, block_n)
        for k in np.flatnonzero(sizes >= 2)
    ]
    n = int(sizes.sum())
    return _stack(parts, block_m, block_n, n, n, plan.r, plan.total_pairs)


def catalog_for_block_split(plan: BlockSplitPlan, block_m: int = 128,
                            block_n: int = 128) -> TileCatalog:
    """The match-task table is already tile geometry — compile directly."""
    parts = [
        _task_tiles(int(plan.task_a_start[t]), int(plan.task_a_len[t]),
                    int(plan.task_b_start[t]), int(plan.task_b_len[t]),
                    bool(plan.task_triangular[t]),
                    int(plan.task_reducer[t]), block_m, block_n)
        for t in range(plan.task_block.shape[0])
    ]
    n = int(plan.block_sizes.sum())
    return _stack(parts, block_m, block_n, n, n, plan.r, plan.total_pairs)


def catalog_for_pair_range(plan: PairRangePlan, block_m: int = 128,
                           block_n: int = 128) -> TileCatalog:
    """Range k ∩ block = a corner-cut triangle segment (x_lo..x_hi columns,
    prefix/suffix cuts at (x_lo, y_lo) / (x_hi, y_hi)) — expressed with the
    catalog's lb/ub predicates, O(1) scalars per (range, block)."""
    parts = []
    for k in range(plan.r):
        for blk, x_lo, y_lo, x_hi, y_hi in range_block_segments(plan, k):
            e0 = int(plan.estart[blk])
            n = int(plan.block_sizes[blk])
            c0 = e0 + (y_lo if x_hi == x_lo else x_lo + 1)
            c1 = e0 + (y_hi + 1 if x_hi == x_lo else n)
            parts.append(_task_tiles(
                e0 + x_lo, x_hi - x_lo + 1, c0, c1 - c0, True, k,
                block_m, block_n,
                lb=(e0 + x_lo, e0 + y_lo), ub=(e0 + x_hi, e0 + y_hi)))
    n_rows = int(plan.block_sizes.sum())
    return _stack(parts, block_m, block_n, n_rows, n_rows,
                  plan.r, plan.total_pairs)


def catalog_for_sorted_neighborhood(plan: SortedNeighborhoodPlan,
                                    block_m: int = 128,
                                    block_n: int = 128) -> TileCatalog:
    """Compile the window-w band over the sort order (features must be in
    sorted-key order). Range k ∩ band = rows i_lo..i_hi with a prefix cut
    at (i_lo, j_lo) and a suffix cut at (i_hi, j_hi) — the PairRange
    corner-cut machinery — plus the band predicate col − row < w, the
    first non-block-aligned tile geometry in the catalog vocabulary.
    Tiles are pruned to the ones actually intersecting the band."""
    n, we = plan.n, plan.w_eff
    parts = []
    for k in range(plan.r):
        seg = band_range_segment(plan, k)
        if seg is None:
            continue
        i_lo, j_lo, i_hi, j_hi = seg
        c0 = i_lo + 1
        c1 = min(i_hi + we, n)
        parts.append(_task_tiles(
            i_lo, i_hi - i_lo + 1, c0, c1 - c0, True, k, block_m, block_n,
            lb=(i_lo, j_lo), ub=(i_hi, j_hi), band=we))
    return _stack(parts, block_m, block_n, n, n, plan.r, plan.total_pairs)


def catalog_for_cross(n_a: int, n_b: int, r: int = 1, block_m: int = 128,
                      block_n: int = 128) -> TileCatalog:
    """Full cartesian A × B (the match_⊥(R, R_∅) job): one rectangular
    task over two *different* feature matrices, tiles round-robined over
    r reducers."""
    tiles = _task_tiles(0, n_a, 0, n_b, False, 0, block_m, block_n)
    if tiles.shape[0]:
        tiles[:, RED] = np.arange(tiles.shape[0], dtype=np.int32) % max(r, 1)
    return TileCatalog(tiles=tiles, block_m=block_m, block_n=block_n,
                       n_rows_a=n_a, n_rows_b=n_b, r=max(r, 1),
                       total_pairs=n_a * n_b)


def catalog_for_two_source(plan, block_m: int = 128,
                           block_n: int = 128) -> TileCatalog:
    """Compile a two-source R × S plan (paper Appendix I) to cross tiles.

    The a-side indexes the R blocked layout, the b-side the S blocked
    layout — two *different* feature matrices, so every task is
    rectangular (tri=False). BlockSplit2's match-task table is already
    tile geometry; PairRange2's range ∩ block is a contiguous run of the
    row-major rectangular enumeration — rows x_lo..x_hi with a prefix cut
    at (x_lo, y_lo) and a suffix cut at (x_hi, y_hi), the same lb/ub
    corner-cut predicates the single-source compiler uses (they are plain
    row/col comparisons, agnostic to triangular vs rectangular cells).
    This is the query-vs-corpus hot path of ``er/service.ERService``.
    """
    if isinstance(plan, BlockSplit2Plan):
        parts = [
            _task_tiles(int(plan.task_a_start[t]), int(plan.task_a_len[t]),
                        int(plan.task_b_start[t]), int(plan.task_b_len[t]),
                        False, int(plan.task_reducer[t]), block_m, block_n)
            for t in range(plan.task_block.shape[0])
        ]
        return _stack(parts, block_m, block_n, plan.n_rows_r, plan.n_rows_s,
                      plan.r, plan.total_pairs)
    if isinstance(plan, PairRange2Plan):
        parts = []
        for k in range(plan.r):
            for blk, x_lo, y_lo, x_hi, y_hi in range_block_segments_2src(plan, k):
                e0r = int(plan.er_start[blk])
                e0s = int(plan.es_start[blk])
                ns = int(plan.sizes_s[blk])
                c0 = e0s + (y_lo if x_hi == x_lo else 0)
                c1 = e0s + (y_hi + 1 if x_hi == x_lo else ns)
                parts.append(_task_tiles(
                    e0r + x_lo, x_hi - x_lo + 1, c0, c1 - c0, False, k,
                    block_m, block_n,
                    lb=(e0r + x_lo, e0s + y_lo), ub=(e0r + x_hi, e0s + y_hi)))
        return _stack(parts, block_m, block_n, plan.n_rows_r, plan.n_rows_s,
                      plan.r, plan.total_pairs)
    raise TypeError(f"no two-source catalog compiler for {type(plan).__name__}")


def build_catalog(plan, block_m: int = 128, block_n: int = 128) -> TileCatalog:
    """Dispatch on plan type (Basic / BlockSplit / PairRange / SN / 2src)."""
    if isinstance(plan, BasicPlan):
        return catalog_for_basic(plan, block_m, block_n)
    if isinstance(plan, BlockSplitPlan):
        return catalog_for_block_split(plan, block_m, block_n)
    if isinstance(plan, PairRangePlan):
        return catalog_for_pair_range(plan, block_m, block_n)
    if isinstance(plan, SortedNeighborhoodPlan):
        return catalog_for_sorted_neighborhood(plan, block_m, block_n)
    if isinstance(plan, (BlockSplit2Plan, PairRange2Plan)):
        return catalog_for_two_source(plan, block_m, block_n)
    raise TypeError(f"no catalog compiler for {type(plan).__name__}")


def pad_catalog_tiles(catalog: TileCatalog, multiple: int) -> TileCatalog:
    """Pad the tile table to a multiple of ``multiple`` rows with all-zero
    entries (empty validity window r0 == r1 == 0 → no survivors), so a
    chunked scorer sees only one chunk shape — the shape-bucketing the
    serving path relies on for zero steady-state recompiles."""
    t = catalog.num_tiles
    padded = max(multiple, -(-t // multiple) * multiple)
    if padded == t:
        return catalog
    tiles = np.concatenate(
        [catalog.tiles, np.zeros((padded - t, NCOLS), np.int32)], axis=0)
    return TileCatalog(tiles=tiles, block_m=catalog.block_m,
                       block_n=catalog.block_n, n_rows_a=catalog.n_rows_a,
                       n_rows_b=catalog.n_rows_b, r=catalog.r,
                       total_pairs=catalog.total_pairs)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        # Interpret-mode Pallas is a Python emulator — on a non-TPU
        # backend the batched-matmul XLA path IS the production path.
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _pad_pow2(t: int, cap: int) -> int:
    p = 1
    while p < t:
        p *= 2
    return min(p, cap)


def score_catalog(feats_a, catalog: TileCatalog, feats_b=None, *,
                  threshold: float, impl: str = "auto",
                  chunk_tiles: int = 1024) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1 for a whole catalog: survivor candidate pairs.

    Runs the catalog through the kernel in fixed-size chunks (padded to
    powers of two so jit caches a handful of shapes), compacts each
    chunk's (chunk, bm, bn) survivor mask into global (row_a, row_b)
    indices. Returns two int64 arrays.
    """
    import jax.numpy as jnp

    from ..kernels import ops

    impl = _resolve_impl(impl)
    if feats_b is None:
        feats_b = feats_a
    fa = jnp.asarray(feats_a)
    fb = jnp.asarray(feats_b)
    tiles = catalog.tiles
    bm, bn = catalog.block_m, catalog.block_n
    t_total = tiles.shape[0]
    out_a, out_b = [], []
    for lo in range(0, t_total, chunk_tiles):
        chunk = tiles[lo:lo + chunk_tiles]
        padded = _pad_pow2(chunk.shape[0], chunk_tiles)
        if padded != chunk.shape[0]:
            # Empty entries: zero windows (r0 == r1) mask everything out.
            pad = np.zeros((padded - chunk.shape[0], NCOLS), np.int32)
            chunk = np.concatenate([chunk, pad], axis=0)
        mask = np.asarray(ops.pair_scores_catalog(
            fa, fb, jnp.asarray(chunk), threshold=threshold,
            block_m=bm, block_n=bn, impl=impl))
        ti, ii, jj = np.nonzero(mask)
        out_a.append(chunk[ti, A_TILE].astype(np.int64) * bm + ii)
        out_b.append(chunk[ti, B_TILE].astype(np.int64) * bn + jj)
    if not out_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(out_a), np.concatenate(out_b)


_VERIFY_CHUNK = 8_192


def verify_pairs(codes_a, lens_a, codes_b, lens_b, rows_a, rows_b,
                 threshold: float,
                 chunk: int = _VERIFY_CHUNK) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 2: exact normalized edit similarity >= threshold on candidate
    row pairs, in fixed-size padded chunks (one jit compilation)."""
    from .similarity import edit_similarity

    hit_a, hit_b = [], []
    for lo in range(0, rows_a.shape[0], chunk):
        a = rows_a[lo:lo + chunk]
        b = rows_b[lo:lo + chunk]
        pad = chunk - a.shape[0]
        if pad:
            a = np.concatenate([a, np.zeros(pad, a.dtype)])
            b = np.concatenate([b, np.zeros(pad, b.dtype)])
        sim = np.array(edit_similarity(
            codes_a[a], lens_a[a], codes_b[b], lens_b[b]))
        if pad:
            sim[chunk - pad:] = 0.0
        sel = np.flatnonzero(sim >= threshold)
        hit_a.append(a[sel])
        hit_b.append(b[sel])
    if not hit_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(hit_a), np.concatenate(hit_b)


def match_catalog(catalog: TileCatalog, feats_a, codes_a, lens_a, *,
                  feats_b=None, codes_b=None, lens_b=None,
                  threshold: float = 0.8, filter_margin: float = 0.25,
                  impl: str = "auto",
                  chunk_tiles: int = 1024) -> Tuple[np.ndarray, np.ndarray]:
    """Fused filter-and-verify: kernel stage 1 over the tile catalog,
    exact stage 2 on compacted survivors. Returns matched (rows_a, rows_b)
    — indices into the a-side (and b-side, if distinct) arrays."""
    cand_a, cand_b = score_catalog(
        feats_a, catalog, feats_b,
        threshold=threshold - filter_margin, impl=impl,
        chunk_tiles=chunk_tiles)
    if codes_b is None:
        codes_b, lens_b = codes_a, lens_a
    return verify_pairs(codes_a, lens_a, codes_b, lens_b,
                        cand_a, cand_b, threshold)


# ---------------------------------------------------------------------------
# Test oracle
# ---------------------------------------------------------------------------

def enumerate_catalog_pairs(catalog: TileCatalog) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize every pair a catalog covers (numpy, O(P) — tests only).

    Applies the exact kernel predicate per tile; the parity tests assert
    this equals the plan's own pair enumeration, i.e. the catalog covers
    each planned pair exactly once.
    """
    bm, bn = catalog.block_m, catalog.block_n
    gi = np.arange(bm)[:, None]
    gj = np.arange(bn)[None, :]
    out_a, out_b = [], []
    for e in catalog.tiles:
        rows = e[A_TILE].astype(np.int64) * bm + gi
        cols = e[B_TILE].astype(np.int64) * bn + gj
        keep = (rows >= e[R0]) & (rows < e[R1]) & (cols >= e[C0]) & (cols < e[C1])
        if e[TRI]:
            keep &= rows < cols
        keep &= (rows > e[LB_R]) | (cols >= e[LB_C])
        keep &= (rows < e[UB_R]) | (cols <= e[UB_C])
        if e[BAND]:
            keep &= cols - rows < e[BAND]
        ii, jj = np.nonzero(keep)
        out_a.append(rows[ii, 0])
        out_b.append(cols[0, jj])
    if not out_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(out_a), np.concatenate(out_b)
