"""Compatibility shims for the tile-catalog executor.

The plan → catalog → schedule → execute pipeline lives in
``er/compiler`` (DESIGN.md §Compiler): ``compiler.plan_to_job`` lowers
any strategy's plan into the MatchJob IR, ``compiler.lower`` tiles it,
``compiler.schedule_tiles`` places tiles on reducers/devices and
``compiler.execute`` runs stage 1 anywhere. This module keeps the
historical entry points — the per-strategy ``catalog_for_*`` builders,
``build_catalog``, ``score_catalog``/``verify_pairs``/``match_catalog``
and the pair-enumeration test oracle — as thin wrappers so existing
callers and tests keep working.
"""
from __future__ import annotations

from .compiler import (  # noqa: F401
    A_TILE, B_TILE, R0, R1, C0, C1, TRI, LB_R, LB_C, UB_R, UB_C, BAND, RED,
    NCOLS,
    TileCatalog,
    cross_job,
    enumerate_catalog_pairs,
    lower,
    match_catalog,
    plan_to_job,
    score_catalog,
    verify_pairs,
)
from .compiler.execute import _resolve_impl  # noqa: F401  (service shim)
from .compiler.ir import NO_LB as _NO_LB, NO_UB as _NO_UB  # noqa: F401
from .compiler.lower import pad_catalog as pad_catalog_tiles  # noqa: F401

__all__ = [
    "TileCatalog",
    "catalog_for_basic",
    "catalog_for_block_split",
    "catalog_for_pair_range",
    "catalog_for_sorted_neighborhood",
    "catalog_for_cross",
    "catalog_for_two_source",
    "build_catalog",
    "pad_catalog_tiles",
    "score_catalog",
    "verify_pairs",
    "match_catalog",
    "enumerate_catalog_pairs",
]


def build_catalog(plan, block_m: int = 128, block_n: int = 128) -> TileCatalog:
    """Compile any plan (Basic / BlockSplit / PairRange / SN / 2src) to a
    tile catalog — ``lower(plan_to_job(plan))``."""
    return lower(plan_to_job(plan), block_m, block_n)


def catalog_for_cross(n_a: int, n_b: int, r: int = 1, block_m: int = 128,
                      block_n: int = 128) -> TileCatalog:
    """Full cartesian A × B (the match_⊥(R, R_∅) job)."""
    return lower(cross_job(n_a, n_b, r), block_m, block_n)


# Per-strategy aliases: every one is the same lowering now.
catalog_for_basic = build_catalog
catalog_for_block_split = build_catalog
catalog_for_pair_range = build_catalog
catalog_for_sorted_neighborhood = build_catalog
catalog_for_two_source = build_catalog
