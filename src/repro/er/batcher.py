"""Async super-batching front-end for :class:`~.service.ERService`.

``ERService.match`` is synchronous: one caller, one micro-batch, one
pass through the bucket contract. Under concurrent traffic that leaves
the balanced schedules idle between micro-batches — the serving
throughput problem dynamic batching solves for model servers applies
unchanged here. :class:`ERBatcher` closes the gap:

  * **Super-batching.** ``submit(query_titles)`` returns a
    ``concurrent.futures.Future`` immediately; concurrent submissions
    accumulate into ONE super-batch that flushes when it reaches
    ``max_batch`` queries (flush-on-full) or when the oldest pending
    request has waited ``max_delay_s`` (flush-on-deadline). The
    super-batch pads to the same shape buckets sequential traffic uses,
    so steady state stays at ZERO XLA recompiles.
  * **Exact demultiplexing.** A super-batch is the concatenation of its
    member requests, and the service's streaming ≡ batch contract says
    the match set of a concatenation equals the union over any split —
    so slicing each member's pairs back out by query offset yields
    EXACTLY what a sequential ``match`` would have returned. Response
    metadata (coverage, attempts, steals) is shared-fate: every member
    reports the super-batch it rode in.
  * **Plan/execute pipeline.** Planning (featurize + fold into the
    BDM + lower to catalogs, host-side, under the service's host lock)
    and execution (kernel launches) run on separate threads connected
    by a depth-1 queue — a two-deep pipeline in which super-batch k+1
    plans while super-batch k's kernels are in flight.
  * **Per-tenant admission.** A token bucket per tenant id (refill
    ``tenant_rate`` queries/s, burst ``tenant_burst``) rejects the
    excess of a hot tenant with :class:`AdmissionError` (carrying
    ``retry_after_s``) instead of letting it starve the shared bucket.

The batcher requires the service refactor that made requests
thread-safe: request state lives on a per-request context, host-side
index mutation is locked, and the request deadline is armed once per
request (so a super-batch spends one shared budget).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from .service import ERService, MatchResponse

__all__ = ["ERBatcher", "AdmissionError"]


class AdmissionError(RuntimeError):
    """A tenant's token bucket cannot cover the submitted queries.
    Clients should back off ``retry_after_s`` seconds — the bucket will
    have refilled enough for this request by then (requests larger than
    the burst can never be admitted whole; split them)."""

    def __init__(self, msg: str, retry_after_s: float, tenant: str):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)
        self.tenant = tenant


class _TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill toward ``burst``.
    Not thread-safe on its own — the batcher serializes access."""

    def __init__(self, rate: float, burst: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.t = time.monotonic()

    def try_take(self, n: int) -> float:
        """Take ``n`` tokens; returns 0.0 on success, else the seconds
        until the bucket will hold ``n`` (capped at the burst)."""
        now = time.monotonic()
        self.tokens = min(self.burst, self.tokens + (now - self.t) * self.rate)
        self.t = now
        if self.tokens >= n:
            self.tokens -= n
            return 0.0
        need = min(float(n), self.burst) - self.tokens
        return max(need / self.rate, 1e-3)


@dataclass
class _Pending:
    titles: List[str]
    nq: int
    tenant: str
    future: Future
    arrived: float


@dataclass
class _Super:
    """One assembled super-batch: member requests with their offsets
    into the concatenated query list, plus the shared request context
    (one deadline for the whole super-batch)."""
    members: List[_Pending]
    offsets: np.ndarray            # (len(members),) start offset of each
    total: int
    ctx: object
    responses: List[MatchResponse] = field(default_factory=list)

    def __post_init__(self):
        self.responses = [MatchResponse() for _ in self.members]


@dataclass
class _WorkItem:
    sup: _Super
    pb: object                     # _PlannedBatch for queries [lo, lo+nq)
    lo: int
    last: bool


_SENTINEL = object()


class ERBatcher:
    """Dynamic super-batcher over an :class:`ERService` (module
    docstring). Use as a context manager, or call :meth:`close`.

    Parameters:
      * ``max_delay_s`` — flush-on-deadline latency bound: the oldest
        pending request never waits longer than this for the bucket to
        fill (queueing behind an in-flight super-batch can add more).
      * ``max_batch`` — flush-on-full size; defaults to the service's
        top query bucket so a full super-batch is one bucket-shaped
        dispatch. Requests larger than ``max_batch`` are accepted and
        internally sliced (they occupy a super-batch of their own).
      * ``tenant_rate`` / ``tenant_burst`` — per-tenant token-bucket
        admission in queries/s; None disables admission control.
    """

    def __init__(self, service: ERService, *, max_delay_s: float = 0.005,
                 max_batch: Optional[int] = None,
                 tenant_rate: Optional[float] = None,
                 tenant_burst: Optional[float] = None):
        self.service = service
        self.max_delay_s = float(max_delay_s)
        cap = service._buckets[-1]
        self.max_batch = int(max_batch) if max_batch is not None else cap
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._cap = cap
        self._tenant_rate = tenant_rate
        self._tenant_burst = (float(tenant_burst) if tenant_burst is not None
                              else float(max(self.max_batch, tenant_rate or 0)))
        self._tenants: Dict[str, _TokenBucket] = {}
        self._pending: deque = deque()
        self._cond = threading.Condition()
        self._outstanding = 0
        self._closed = False
        # Depth-1 handoff queue == two-deep pipeline: one super-batch
        # planning (or planned, waiting) while one executes.
        import queue as _queue
        self._planned: _queue.Queue = _queue.Queue(maxsize=1)
        self.stats: Dict = {"requests": 0, "queries": 0, "rejected": 0,
                            "super_batches": 0, "max_fill": 0,
                            "flush_full": 0, "flush_deadline": 0}
        self._planner = threading.Thread(
            target=self._plan_loop, name="erbatcher-plan", daemon=True)
        self._executor = threading.Thread(
            target=self._exec_loop, name="erbatcher-exec", daemon=True)
        self._planner.start()
        self._executor.start()

    # -- client API ------------------------------------------------------

    def submit(self, query_titles: Sequence[str],
               tenant: str = "default") -> "Future[MatchResponse]":
        """Enqueue one micro-batch; resolves to the same
        :class:`MatchResponse` match set a sequential
        ``service.match(query_titles)`` would return."""
        titles = list(query_titles)
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("ERBatcher is closed")
            if self._tenant_rate is not None and titles:
                bucket = self._tenants.get(tenant)
                if bucket is None:
                    bucket = self._tenants[tenant] = _TokenBucket(
                        self._tenant_rate, self._tenant_burst)
                wait = bucket.try_take(len(titles))
                if wait > 0.0:
                    self.stats["rejected"] += 1
                    raise AdmissionError(
                        f"tenant {tenant!r} exceeded {self._tenant_rate} "
                        f"queries/s (burst {self._tenant_burst:g})",
                        retry_after_s=wait, tenant=tenant)
            self.stats["requests"] += 1
            self.stats["queries"] += len(titles)
            if not titles:
                fut.set_result(MatchResponse())
                return fut
            self._pending.append(_Pending(
                titles=titles, nq=len(titles), tenant=tenant,
                future=fut, arrived=time.monotonic()))
            self._outstanding += 1
            self._cond.notify_all()
        return fut

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has resolved (or
        ``timeout`` seconds passed); returns whether the queue drained."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._outstanding > 0:
                rem = None if end is None else end - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self._cond.wait(rem)
        return True

    def close(self):
        """Drain pending work, stop both threads. Idempotent."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._planner.join()
        self._executor.join()

    def __enter__(self) -> "ERBatcher":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- planner thread --------------------------------------------------

    def _fill(self) -> int:
        return sum(p.nq for p in self._pending)

    def _take_members(self) -> Optional[List[_Pending]]:
        """Wait for work, honor the flush policy, pop one super-batch's
        members. Returns None when closed and drained."""
        with self._cond:
            while not self._pending:
                if self._closed:
                    return None
                self._cond.wait()
            # Accumulate until full or the OLDEST request's delay budget
            # is spent (closing flushes immediately).
            deadline = self._pending[0].arrived + self.max_delay_s
            while (self._fill() < self.max_batch and not self._closed):
                rem = deadline - time.monotonic()
                if rem <= 0:
                    break
                self._cond.wait(rem)
            if self._fill() >= self.max_batch:
                self.stats["flush_full"] += 1
            else:
                self.stats["flush_deadline"] += 1
            members: List[_Pending] = [self._pending.popleft()]
            total = members[0].nq
            while self._pending and \
                    total + self._pending[0].nq <= self.max_batch:
                p = self._pending.popleft()
                members.append(p)
                total += p.nq
            self.stats["super_batches"] += 1
            self.stats["max_fill"] = max(self.stats["max_fill"], total)
            return members

    def _plan_loop(self):
        svc = self.service
        while True:
            members = self._take_members()
            if members is None:
                self._planned.put(_SENTINEL)
                return
            try:
                titles: List[str] = []
                offsets = np.zeros(len(members), np.int64)
                for i, p in enumerate(members):
                    offsets[i] = len(titles)
                    titles.extend(p.titles)
                sup = _Super(members=members, offsets=offsets,
                             total=len(titles), ctx=svc._new_request_ctx())
                slices = list(range(0, sup.total, self._cap))
                for k, lo in enumerate(slices):
                    pb = svc._plan_batch(titles[lo:lo + self._cap],
                                         sup.ctx, record=True)
                    self._planned.put(_WorkItem(
                        sup=sup, pb=pb, lo=lo, last=(k == len(slices) - 1)))
            except BaseException as e:      # plan failed: fail the super
                self._fail_super(members, e)

    # -- executor thread -------------------------------------------------

    def _exec_loop(self):
        svc = self.service
        while True:
            item = self._planned.get()
            if item is _SENTINEL:
                return
            sup = item.sup
            try:
                part = svc._execute_batch(item.pb, sup.ctx)
                self._demux(sup, part, item.lo)
                if item.last:
                    self._resolve_super(sup)
            except BaseException as e:
                self._fail_super(sup.members, e)

    def _demux(self, sup: _Super, part: MatchResponse, lo: int):
        """Route one executed slice's pairs to the member covering each
        query offset; shared-fate metadata folds into every member."""
        offs = sup.offsets
        for a, b in part:
            g = lo + b
            i = int(np.searchsorted(offs, g, side="right")) - 1
            sup.responses[i].add((a, g - int(offs[i])))
        for resp in sup.responses:
            resp.attempts = max(resp.attempts, part.attempts)
            resp.recovered_tiles += part.recovered_tiles
            resp.planned_cost += part.planned_cost
            resp.scored_cost += part.scored_cost
            resp.steals += part.steals
            resp.stolen_tiles += part.stolen_tiles
            resp.degraded = resp.degraded or part.degraded

    def _resolve_super(self, sup: _Super):
        with self._cond:
            for p, resp in zip(sup.members, sup.responses):
                if not p.future.done():
                    p.future.set_result(resp)
                    self._outstanding -= 1
            self._cond.notify_all()

    def _fail_super(self, members: List[_Pending], exc: BaseException):
        with self._cond:
            for p in members:
                if not p.future.done():
                    p.future.set_exception(exc)
                    self._outstanding -= 1
            self._cond.notify_all()
