"""Blocking-key generation (paper §I, §VI).

The paper's default key is the first three letters of the title; the
robustness study (Fig. 9) replaces it with a controlled exponential block
distribution ``|Φ_k| ∝ e^{−s·k}`` over b=100 blocks. Both are provided.
Entities without a usable key get block id −1 (handled by the pipeline's
match_⊥ decomposition, paper §III / Appendix I).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["prefix_key", "prefix_block_ids", "dense_block_ids",
           "exponential_block_ids", "exponential_block_sizes",
           "sn_sort_keys", "sn_sort_order"]


def prefix_key(title: str, k: int = 3) -> str | None:
    """The paper's blocking key for one entity: first k letters of the
    normalized title, or None when no key can be formed (→ block −1,
    the match_⊥ decomposition). THE single definition of the key rule —
    the batch pipeline and the resident service must derive identical
    keys or the streaming ≡ batch contract breaks."""
    key = title.strip().lower()[:k]
    return key if key else None


def prefix_block_ids(titles: Sequence[str], k: int = 3) -> Tuple[np.ndarray, List[str]]:
    """First-k-letters blocking. Returns (block_ids int64 with −1 for
    entities lacking a key, list of key strings by block id).

    Block ids are assigned in first-occurrence order — the paper's
    "(arbitrary) order of the blocks from the reduce output" (§III-B).
    """
    ids = np.empty(len(titles), np.int64)
    keys: dict[str, int] = {}
    names: List[str] = []
    for i, t in enumerate(titles):
        key = prefix_key(t, k)
        if key is None:
            ids[i] = -1
            continue
        if key not in keys:
            keys[key] = len(names)
            names.append(key)
        ids[i] = keys[key]
    return ids, names


def sn_sort_keys(titles: Sequence[str]) -> List[str]:
    """Sorted-Neighborhood sort keys (arXiv:1010.3053): the normalized
    title itself — the lexicographic analog of the prefix blocking key,
    but *total*: every entity gets a key (empty titles sort first), so SN
    has no match_⊥ decomposition."""
    return [t.strip().lower() for t in titles]


def sn_sort_order(titles: Sequence[str]) -> np.ndarray:
    """Stable argsort of :func:`sn_sort_keys` — the SN sort pass (the
    MR-implementation's Job 1). Returns int64 positions: ``order[p]`` is
    the original index of the entity at sorted position ``p``."""
    return np.argsort(np.asarray(sn_sort_keys(titles)),
                      kind="stable").astype(np.int64)


def dense_block_ids(keys: Sequence) -> Tuple[np.ndarray, list]:
    """Factorize arbitrary hashable keys into dense [0, b) ids."""
    ids = np.empty(len(keys), np.int64)
    seen: dict = {}
    names: list = []
    for i, key in enumerate(keys):
        if key not in seen:
            seen[key] = len(names)
            names.append(key)
        ids[i] = seen[key]
    return ids, names


def exponential_block_sizes(n_entities: int, b: int, s: float) -> np.ndarray:
    """Block sizes ∝ e^{−s·k}, k=0..b−1, summing to n_entities (Fig. 9).

    Largest-remainder rounding keeps the total exact; every block keeps at
    least one entity where possible.
    """
    w = np.exp(-s * np.arange(b, dtype=np.float64))
    ideal = w / w.sum() * n_entities
    sizes = np.floor(ideal).astype(np.int64)
    rem = n_entities - int(sizes.sum())
    frac_order = np.argsort(-(ideal - sizes), kind="stable")
    sizes[frac_order[:rem]] += 1
    return sizes


def exponential_block_ids(n_entities: int, b: int, s: float,
                          rng: np.random.Generator | None = None) -> np.ndarray:
    """Assign entities to blocks with the Fig. 9 exponential skew; the
    assignment is shuffled so input partitions mix blocks (the unsorted
    regime of Fig. 11)."""
    sizes = exponential_block_sizes(n_entities, b, s)
    ids = np.repeat(np.arange(b, dtype=np.int64), sizes)
    if rng is not None:
        rng.shuffle(ids)
    return ids
