"""Plan IR: the MatchJob every planner lowers into.

A *match job* is the strategy-agnostic description of a pairwise
workload: a flat int64 table of **task rectangles** over the blocked
feature layout(s), each carrying the same predicate vocabulary the
kernel evaluates per cell (validity window, triangular mask, PairRange
corner cuts, the Sorted Neighborhood band) plus the planner's reducer
attribution. Lowering a plan to a MatchJob is the ONLY strategy-aware
step in the execution stack — everything downstream (tiling, cost
modeling, scheduling, kernel dispatch) is one shared implementation.

Task columns (TASK_NCOLS = 11, int64):

    a0 alen  b0 blen  tri  lb_r lb_c  ub_r ub_c  band  red

``[a0, a0+alen) × [b0, b0+blen)`` is the task's cell window in global
rows of the a-/b-side matrices; ``tri`` demands row < col (self-join
tasks); the lb/ub pairs encode the corner cuts ``(row > lb_r) | (col >=
lb_c)`` and ``(row < ub_r) | (col <= ub_c)``; ``band > 0`` demands
``col − row < band``. ``red`` is the planner's reduce-task attribution
(:data:`RED_FREE` = "unassigned — let the scheduler place my tiles").

The catalog columns (NCOLS = 13) are owned by ``kernels.pair_sim`` —
this module re-exports them so the rest of the system has a single
import point instead of the old executor → kernels re-export chain.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ...core.basic import BasicPlan
from ...core.block_split import BlockSplitPlan
from ...core.pair_range import PairRangePlan, range_block_segments
from ...core.sorted_neighborhood import (SortedNeighborhoodPlan,
                                         band_range_segment)
from ...core.two_source import (BlockSplit2Plan, PairRange2Plan,
                                range_block_segments_2src)
from ...kernels.pair_sim import NCOLS

__all__ = [
    "NCOLS",
    "A_TILE", "B_TILE", "R0", "R1", "C0", "C1", "TRI",
    "LB_R", "LB_C", "UB_R", "UB_C", "BAND", "RED",
    "TASK_NCOLS",
    "T_A0", "T_ALEN", "T_B0", "T_BLEN", "T_TRI",
    "T_LB_R", "T_LB_C", "T_UB_R", "T_UB_C", "T_BAND", "T_RED",
    "NO_LB", "NO_UB", "RED_FREE",
    "MatchJob",
    "task_row",
    "make_job",
    "TileCatalog",
    "plan_to_job",
    "cross_job",
]

# Catalog column indices (mirrors kernels.pair_sim's layout comment).
(A_TILE, B_TILE, R0, R1, C0, C1, TRI, LB_R, LB_C, UB_R, UB_C, BAND,
 RED) = range(NCOLS)

# Task column indices.
TASK_NCOLS = 11
(T_A0, T_ALEN, T_B0, T_BLEN, T_TRI, T_LB_R, T_LB_C, T_UB_R, T_UB_C,
 T_BAND, T_RED) = range(TASK_NCOLS)

NO_LB = -1           # rows are >= 0, so row > -1 always holds
NO_UB = 2 ** 30      # rows are < 2^30, so row < 2^30 always holds
RED_FREE = -1        # task has no planner attribution: scheduler's choice


@dataclass(frozen=True)
class MatchJob:
    """A compiled plan, pre-tiling: T corner-cut task rectangles that
    together cover every planned pair exactly once."""
    tasks: np.ndarray      # (T, TASK_NCOLS) int64
    n_rows_a: int          # LHS feature-matrix rows the tasks index into
    n_rows_b: int          # RHS rows (== n_rows_a for self-join jobs)
    r: int                 # planner reduce tasks (red column ∈ [0, r))
    total_pairs: int       # planned pair count (exact, from the plan)
    self_join: bool = True  # a-side and b-side are the same matrix

    @property
    def num_tasks(self) -> int:
        return int(self.tasks.shape[0])


@dataclass(frozen=True)
class TileCatalog:
    """A lowered job: T MXU tiles covering every planned pair once."""
    tiles: np.ndarray      # (T, NCOLS) int32
    block_m: int
    block_n: int
    n_rows_a: int          # LHS feature-matrix rows the tiles index into
    n_rows_b: int          # RHS rows (== n_rows_a for single-source plans)
    r: int                 # reduce tasks (tiles[:, RED] ∈ [0, r))
    total_pairs: int       # planned pair count (exact, from the plan)

    @property
    def num_tiles(self) -> int:
        return int(self.tiles.shape[0])


def task_row(a0, alen, b0, blen, tri, red,
              lb=(NO_LB, NO_LB), ub=(NO_UB, NO_UB), band=0):
    return (int(a0), int(alen), int(b0), int(blen), int(tri),
            int(lb[0]), int(lb[1]), int(ub[0]), int(ub[1]),
            int(band), int(red))


def make_job(rows, n_rows_a, n_rows_b, r, total, self_join=True) -> MatchJob:
    tasks = (np.asarray(rows, np.int64) if rows
             else np.zeros((0, TASK_NCOLS), np.int64))
    return MatchJob(tasks=tasks, n_rows_a=int(n_rows_a),
                    n_rows_b=int(n_rows_b), r=int(r),
                    total_pairs=int(total), self_join=self_join)


# ---------------------------------------------------------------------------
# Per-strategy lowerings (the six former catalog_for_* builders)
# ---------------------------------------------------------------------------

def _job_basic(plan: BasicPlan) -> MatchJob:
    """One triangular task per block with >= 1 pair, on its reducer."""
    sizes = plan.block_sizes
    estart = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)[:-1]])
    rows = [
        task_row(estart[k], sizes[k], estart[k], sizes[k], True,
                  plan.block_reducer[k])
        for k in np.flatnonzero(sizes >= 2)
    ]
    n = int(sizes.sum())
    return make_job(rows, n, n, plan.r, plan.total_pairs)


def _job_block_split(plan: BlockSplitPlan) -> MatchJob:
    """The match-task table is already task geometry — copy it over."""
    rows = [
        task_row(plan.task_a_start[t], plan.task_a_len[t],
                  plan.task_b_start[t], plan.task_b_len[t],
                  bool(plan.task_triangular[t]), plan.task_reducer[t])
        for t in range(plan.task_block.shape[0])
    ]
    n = int(plan.block_sizes.sum())
    return make_job(rows, n, n, plan.r, plan.total_pairs)


def _job_pair_range(plan: PairRangePlan) -> MatchJob:
    """Range k ∩ block = a corner-cut triangle segment (x_lo..x_hi columns,
    prefix/suffix cuts at (x_lo, y_lo) / (x_hi, y_hi)) — O(1) scalars per
    (range, block)."""
    rows = []
    for k in range(plan.r):
        for blk, x_lo, y_lo, x_hi, y_hi in range_block_segments(plan, k):
            e0 = int(plan.estart[blk])
            n = int(plan.block_sizes[blk])
            c0 = e0 + (y_lo if x_hi == x_lo else x_lo + 1)
            c1 = e0 + (y_hi + 1 if x_hi == x_lo else n)
            rows.append(task_row(
                e0 + x_lo, x_hi - x_lo + 1, c0, c1 - c0, True, k,
                lb=(e0 + x_lo, e0 + y_lo), ub=(e0 + x_hi, e0 + y_hi)))
    n_rows = int(plan.block_sizes.sum())
    return make_job(rows, n_rows, n_rows, plan.r, plan.total_pairs)


def _job_sorted_neighborhood(plan: SortedNeighborhoodPlan) -> MatchJob:
    """The window-w band over the sort order (features must be in
    sorted-key order). Range k ∩ band = rows i_lo..i_hi with corner cuts
    at (i_lo, j_lo) / (i_hi, j_hi), plus the band predicate
    col − row < w."""
    n, we = plan.n, plan.w_eff
    rows = []
    for k in range(plan.r):
        seg = band_range_segment(plan, k)
        if seg is None:
            continue
        i_lo, j_lo, i_hi, j_hi = seg
        c0 = i_lo + 1
        c1 = min(i_hi + we, n)
        rows.append(task_row(
            i_lo, i_hi - i_lo + 1, c0, c1 - c0, True, k,
            lb=(i_lo, j_lo), ub=(i_hi, j_hi), band=we))
    return make_job(rows, n, n, plan.r, plan.total_pairs)


def _job_two_source(plan) -> MatchJob:
    """Two-source R × S plans (paper Appendix I): the a-side indexes the
    R blocked layout, the b-side the S layout — two *different* feature
    matrices, so every task is rectangular (tri=False)."""
    if isinstance(plan, BlockSplit2Plan):
        rows = [
            task_row(plan.task_a_start[t], plan.task_a_len[t],
                      plan.task_b_start[t], plan.task_b_len[t],
                      False, plan.task_reducer[t])
            for t in range(plan.task_block.shape[0])
        ]
        return make_job(rows, plan.n_rows_r, plan.n_rows_s, plan.r,
                    plan.total_pairs, self_join=False)
    rows = []
    for k in range(plan.r):
        for blk, x_lo, y_lo, x_hi, y_hi in range_block_segments_2src(plan, k):
            e0r = int(plan.er_start[blk])
            e0s = int(plan.es_start[blk])
            ns = int(plan.sizes_s[blk])
            c0 = e0s + (y_lo if x_hi == x_lo else 0)
            c1 = e0s + (y_hi + 1 if x_hi == x_lo else ns)
            rows.append(task_row(
                e0r + x_lo, x_hi - x_lo + 1, c0, c1 - c0, False, k,
                lb=(e0r + x_lo, e0s + y_lo), ub=(e0r + x_hi, e0s + y_hi)))
    return make_job(rows, plan.n_rows_r, plan.n_rows_s, plan.r,
                plan.total_pairs, self_join=False)


def cross_job(n_a: int, n_b: int, r: int = 1) -> MatchJob:
    """Full cartesian A × B (the match_⊥(R, R_∅) job): one rectangular
    task over two different matrices with no planner attribution — its
    tiles are the scheduler's to place (RED_FREE; the legacy shim and
    the round-robin policy spread them mod r)."""
    rows = []
    if n_a > 0 and n_b > 0:
        rows.append(task_row(0, n_a, 0, n_b, False, RED_FREE))
    return make_job(rows, n_a, n_b, max(r, 1), n_a * n_b, self_join=False)


def plan_to_job(plan) -> MatchJob:
    """Dispatch on plan type (Basic / BlockSplit / PairRange / SN / 2src)
    — the single entry point subsuming the per-strategy builders."""
    if isinstance(plan, BasicPlan):
        return _job_basic(plan)
    if isinstance(plan, BlockSplitPlan):
        return _job_block_split(plan)
    if isinstance(plan, PairRangePlan):
        return _job_pair_range(plan)
    if isinstance(plan, SortedNeighborhoodPlan):
        return _job_sorted_neighborhood(plan)
    if isinstance(plan, (BlockSplit2Plan, PairRange2Plan)):
        return _job_two_source(plan)
    raise TypeError(f"no job lowering for {type(plan).__name__}")
