"""Execution: one generic driver for every lowered + scheduled catalog.

``execute(catalog, feats_a, ...)`` runs stage 1 (kernel cosine filter)
for ANY match job — single host or on a device mesh — and returns the
compacted survivor candidates; ``verify_pairs`` is the exact stage 2 and
``match_catalog`` fuses the two. The mesh path covers the three data
flows that used to be separate near-duplicate shard_map wrappers:

  * **self** — self-join: features row-sharded, each device all_gathers
    them and scores its tile shard (the shuffle of the paper's Job 2).
  * **cross** — two-source: the a-side (corpus) row-sharded and
    gathered, the b-side (query batch) replicated.
  * **halo** — RepSN: features row-sharded in sorted order, each device
    fetches only the ``halo`` boundary rows of the following shards via
    ⌈halo/n_loc⌉ chained neighbor ``ppermute`` hops (the last hop sends
    only the final partial strip) instead of all-gathering; tiles are in
    shard-local coordinates and ``base`` shifts survivors back to
    global rows.

The self/cross gathers take a ``comms`` policy (see ``compiler.comms``):
``"flat"`` is the all_gather above; ``"ring"`` assembles only the
``hops`` forward strips a device's tiles actually read via chained
``ppermute``; ``"hierarchical"`` runs an intra-group ring then
inter-group panel hops. Both rely on the planner's locality tile
placement and buffer-local tile rewrite — ``execute(comms=...)`` wires
all of it. A ``model_axis`` additionally column-shards the features:
each device scores (n_loc, d/n_model) panels into *partial* tile scores
and a ``psum`` over ``model`` combines them before the threshold +
catalog-predicate epilogue (which is meaningless on partials). Every
gather/hop/psum's bytes-received-per-device land in
``stage1_stats["interconnect"]``.

``make_scorer`` builds the jitted per-shard scorer ONCE — resident
services hold one and reuse it for every micro-batch (jit caches by
function identity, so a per-call closure would retrace every batch).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .comms import (COMMS_POLICIES, CommsPlan, halo_bytes_per_device,
                    plan_comms, psum_bytes_per_device, rewrite_tiles_local)
from .faults import DeviceKilledError, FaultInjector, TransientScorerError
from .feedback import N_TILE_CLASSES, EwmaCostModel, tile_class
from .ir import A_TILE, B_TILE, NCOLS, TileCatalog
from .lower import pad_tiles
from .schedule import (NoHealthyDevicesError, Schedule, schedule_tiles,
                       tile_costs, tiles_for_devices)

__all__ = [
    "CatalogScorer",
    "execute",
    "execute_supervised",
    "make_scorer",
    "score_catalog",
    "stage1_stats",
    "verify_pairs",
    "match_catalog",
    "shard_sane",
    "ShardRecord",
    "SupervisedReport",
    "RecoveryFailedError",
]


# shard_map moved from jax.experimental to the top-level namespace (with
# check_rep renamed check_vma) across the jax versions we support; every
# shard_map call site in the repo goes through this shim.
try:
    _shard_map_new = jax.shard_map

    def _smap(f, mesh, in_specs, out_specs):
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def _smap(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        # Interpret-mode Pallas is a Python emulator — on a non-TPU
        # backend the batched-matmul XLA path IS the production path.
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _compact_on_device(impl: str) -> bool:
    """True when ``impl`` is a compiled backend whose on-device packing
    epilogue beats a host mask scan. Interpret mode emulates the kernel
    in Python — the one-hot packing epilogue is O(bm·bn·capacity) numpy
    per tile there, so the dense mask (+ np.nonzero) is the honest path."""
    return impl == "xla" or (impl == "pallas"
                             and jax.default_backend() == "tpu")


def _pad_pow2(t: int, cap: int) -> int:
    p = 1
    while p < t:
        p *= 2
    return min(p, cap)


# ---------------------------------------------------------------------------
# Single-host stage 1
# ---------------------------------------------------------------------------

# Host-side instrumentation of stage 1 survivor decoding, keyed by path:
#   compact_decodes  — chunks decoded from the on-device packed epilogue
#   nonzero_decodes  — chunks decoded via the dense mask + np.nonzero
#   compact_overflows — compact chunks whose exact counts exceeded the
#                       capacity, forcing an exact mask-path fallback
# serve_bench asserts nonzero_decodes stays 0 across steady-state
# serving (the compaction epilogue replaced the host round-trip).
# "interconnect" accumulates bytes RECEIVED per device, per data flow,
# summed over kernel launches (each launch re-runs its gather), using
# the exact formulas of ``compiler.comms`` — mesh_bench asserts the
# ring/flat ratio on these counters.
stage1_stats: dict = {"compact_decodes": 0, "nonzero_decodes": 0,
                      "compact_overflows": 0,
                      "interconnect": {"flat_bytes": 0, "ring_bytes": 0,
                                       "hier_intra_bytes": 0,
                                       "hier_inter_bytes": 0,
                                       "halo_bytes": 0, "psum_bytes": 0}}


def _decode_packed(packed: np.ndarray, counts: np.ndarray,
                   chunk: np.ndarray, bm: int, bn: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Packed (T, capacity) survivor slots + exact (T,) counts → global
    (rows_a, rows_b), O(survivors) host work — no scan of dead cells."""
    tot = int(counts.sum())
    if tot == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    ti = np.repeat(np.arange(counts.size), counts)
    starts = np.cumsum(counts) - counts
    slot = np.arange(tot) - np.repeat(starts, counts)
    flat = packed[ti, slot].astype(np.int64)
    rows_a = chunk[ti, A_TILE].astype(np.int64) * bm + flat // bn
    rows_b = chunk[ti, B_TILE].astype(np.int64) * bn + flat % bn
    return rows_a, rows_b


def score_catalog(feats_a, catalog: TileCatalog, feats_b=None, *,
                  threshold: float, impl: str = "auto",
                  chunk_tiles: int = 1024, compact: bool = True,
                  compact_capacity: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1 for a whole catalog on one host: survivor candidate pairs.

    Runs the catalog through the kernel in fixed-size chunks (padded to
    powers of two so jit caches a handful of shapes) and compacts each
    chunk's survivors into global (row_a, row_b) indices. With
    ``compact`` (the default on the compiled xla/pallas paths) the
    compaction happens ON DEVICE — the kernel's prefix-sum epilogue
    returns packed slot ids + exact counts and the host decode is
    O(survivors); interpret mode keeps the dense-mask + ``np.nonzero``
    path (a Python emulator gains nothing from an emulated epilogue).

    ``compact_capacity`` bounds the packed slots per tile (default
    bm·bn, which can never overflow). A smaller capacity shrinks the
    device→host transfer; tiles whose EXACT count exceeds it fall back
    to the mask path for that chunk — still exact, counted in
    ``stage1_stats['compact_overflows']``. Returns two int64 arrays.
    """
    from ...kernels import ops

    impl = _resolve_impl(impl)
    if feats_b is None:
        feats_b = feats_a
    fa = jnp.asarray(feats_a)
    fb = jnp.asarray(feats_b)
    tiles = catalog.tiles
    bm, bn = catalog.block_m, catalog.block_n
    t_total = tiles.shape[0]
    use_compact = compact and _compact_on_device(impl)
    capacity = compact_capacity if compact_capacity is not None else bm * bn
    out_a, out_b = [], []
    for lo in range(0, t_total, chunk_tiles):
        chunk = tiles[lo:lo + chunk_tiles]
        padded = _pad_pow2(chunk.shape[0], chunk_tiles)
        if padded != chunk.shape[0]:
            # Empty entries: zero windows (r0 == r1) mask everything out.
            pad = np.zeros((padded - chunk.shape[0], NCOLS), np.int32)
            chunk = np.concatenate([chunk, pad], axis=0)
        chunk_j = jnp.asarray(chunk)
        if use_compact:
            packed, counts = ops.pair_scores_catalog_compact(
                fa, fb, chunk_j, threshold=threshold,
                block_m=bm, block_n=bn, capacity=capacity, impl=impl)
            counts = np.asarray(counts).reshape(-1).astype(np.int64)
            if counts.max(initial=0) <= capacity:
                stage1_stats["compact_decodes"] += 1
                ra, rb = _decode_packed(np.asarray(packed), counts,
                                        chunk, bm, bn)
                out_a.append(ra)
                out_b.append(rb)
                continue
            # Exact counts flagged dropped survivors: re-score this
            # chunk through the dense mask (exactness over speed).
            stage1_stats["compact_overflows"] += 1
        mask = np.asarray(ops.pair_scores_catalog(
            fa, fb, chunk_j, threshold=threshold,
            block_m=bm, block_n=bn, impl=impl))
        stage1_stats["nonzero_decodes"] += 1
        ti, ii, jj = np.nonzero(mask)
        out_a.append(chunk[ti, A_TILE].astype(np.int64) * bm + ii)
        out_b.append(chunk[ti, B_TILE].astype(np.int64) * bn + jj)
    if not out_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(out_a), np.concatenate(out_b)


# ---------------------------------------------------------------------------
# Mesh stage 1
# ---------------------------------------------------------------------------

class CatalogScorer:
    """A jitted per-shard scorer plus the metadata
    :func:`_score_and_compact` needs to decode its output. ``compact``
    scorers return (packed, counts) from the kernel's on-device
    compaction epilogue; mask scorers return dense survivor masks.
    Callable like the bare jitted function (jit identity is preserved —
    the wrapped function is created exactly once), with a lazily built
    mask twin for the exact-fallback path on capacity overflow."""

    def __init__(self, fn, *, compact: bool, capacity: int, mask_factory):
        self._fn = fn
        self.compact = compact
        self.capacity = capacity
        self._mask_factory = mask_factory
        self._mask_twin = None

    def __call__(self, *operands):
        return self._fn(*operands)

    def mask_twin(self) -> "CatalogScorer":
        """The dense-mask scorer with identical routing — built (and
        jitted) only if an overflow ever forces the exact fallback."""
        if self._mask_twin is None:
            self._mask_twin = self._mask_factory()
        return self._mask_twin


def _raw_to_mask(total, tiles, bm: int, bn: int, threshold: float):
    """Threshold + catalog-predicate epilogue on COMBINED tile scores —
    the post-psum half of the model-parallel path (partial scores cannot
    be thresholded; see ``ref.pair_scores_catalog_raw_ref``)."""
    from ...kernels.pair_sim import catalog_tile_mask

    def one(entry, s):
        gi = entry[0] * bm + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        gj = entry[1] * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keep = (s >= threshold) & catalog_tile_mask(entry, gi, gj)
        return keep.astype(jnp.float32)

    return jax.vmap(one)(tiles, total)


def make_scorer(mesh: Mesh, axis: str = "data", *, mode: str = "self",
                threshold: float, block_m: int = 128, block_n: int = 128,
                impl: str = "xla", halo: int = 0, compact: bool = False,
                capacity: Optional[int] = None, comms: str = "flat",
                hops: int = 0, group: int = 1, inter_hops: int = 0,
                model_axis: Optional[str] = None) -> CatalogScorer:
    """Build ONE jitted per-shard catalog scorer for the given data flow.

    mode="self":  scorer(feats_sharded, tiles_chunk)
    mode="cross": scorer(feats_a_sharded, feats_b_replicated, tiles_chunk)
    mode="halo":  scorer(feats_sharded, tiles_chunk) — ⌈halo/n_loc⌉
                  chained neighbor ppermute hops (full strips, then the
                  final partial strip) instead of an all-gather; tiles
                  index the [local ‖ halo] strip and each device
                  receives exactly ``halo`` rows.

    ``comms`` selects the self/cross gather (``compiler.comms``):
    "flat" all_gathers; "ring" runs ``hops`` chained forward ppermutes,
    assembling the contiguous strip window [d·n_loc, d·n_loc +
    (hops+1)·n_loc) — tiles must be rewritten to that buffer's local
    coordinates and placed by the planner's locality rule, which is what
    bounds ``hops``; "hierarchical" assembles each ``group``-strip panel
    with an intra-group ring (reordered to global row order with a roll
    by the device's in-group rank), then exchanges whole panels over
    ``inter_hops`` stride-``group`` hops. Hop counts are compile-time
    constants — resident services pin them and route plans needing more
    hops to a flat scorer instead of recompiling.

    ``model_axis`` column-shards the features (d/n_model per device):
    the gather assembles rows as usual (columns stay local), the kernel
    computes *partial* tile scores via the raw (unthresholded, unmasked)
    op, a ``psum`` over ``model_axis`` combines them, and the threshold
    + predicate epilogue runs on the combined scores — compaction then
    packs post-psum via ``ref.pack_survivor_mask``. Outputs are
    replicated over ``model`` (post-psum), so out_specs stay data-only.
    The psum reassociates the d-dimensional dot, so a score lying within
    float ulps OF THE THRESHOLD ITSELF can flip versus the single-axis
    path — data-axis comms policies by contrast reduce in the same
    order and are bit-exact against flat.

    Each returns (n_dev, chunk, bm, bn) survivor masks — or, with
    ``compact=True`` (compiled backends only; see
    :func:`_compact_on_device`), (n_dev, chunk, capacity) packed slot
    ids + (n_dev, chunk, 1) exact counts from the kernel's on-device
    compaction epilogue, so the host decode is O(survivors) with no
    ``np.nonzero``. ``capacity`` defaults to bm·bn, which can never
    overflow. Build the scorer once per resident service / driver and
    reuse it: jit caches by the wrapped function's identity, so a
    per-call closure would retrace every batch.
    """
    from ...kernels import ops, ref

    cap = capacity if capacity is not None else block_m * block_n
    if comms not in COMMS_POLICIES:
        raise ValueError(f"unknown comms policy {comms!r}")
    if comms != "flat" and mode == "halo":
        raise ValueError("halo mode has its own neighbor exchange; "
                         "comms applies to self/cross gathers only")
    n_data = int(mesh.shape[axis])
    perm_fwd = [(s, (s - 1) % n_data) for s in range(n_data)]

    def _epilogue(mask):
        if compact:
            packed, counts = ref.pack_survivor_mask(mask, cap)
            return packed[None], counts[None]
        return mask[None]

    def _score(a, b, tiles_l):
        if model_axis is not None:
            raw = ops.pair_scores_catalog_raw(
                a, b, tiles_l[0], block_m=block_m, block_n=block_n,
                impl=impl)
            total = jax.lax.psum(raw, model_axis)
            return _epilogue(_raw_to_mask(total, tiles_l[0], block_m,
                                          block_n, threshold))
        if compact:
            packed, counts = ops.pair_scores_catalog_compact(
                a, b, tiles_l[0], threshold=threshold,
                block_m=block_m, block_n=block_n, capacity=cap, impl=impl)
            return packed[None], counts[None]
        mask = ops.pair_scores_catalog(
            a, b, tiles_l[0], threshold=threshold,
            block_m=block_m, block_n=block_n, impl=impl)
        return mask[None]

    def _gather(feats_l):
        if comms == "flat":
            return jax.lax.all_gather(feats_l, axis, tiled=True)
        if comms == "ring":
            # Hop k delivers strip d+k; the buffer is the contiguous
            # global row window starting at this device's own strip.
            parts, cur = [feats_l], feats_l
            for _ in range(hops):
                cur = jax.lax.ppermute(cur, axis, perm_fwd)
                parts.append(cur)
            return jnp.concatenate(parts, axis=0) if hops else feats_l
        g = group
        n_loc = feats_l.shape[0]
        perm_intra = [(s, (s // g) * g + ((s % g) - 1) % g)
                      for s in range(n_data)]
        perm_inter = [(s, (s - g) % n_data) for s in range(n_data)]
        parts, cur = [feats_l], feats_l
        for _ in range(g - 1):
            cur = jax.lax.ppermute(cur, axis, perm_intra)
            parts.append(cur)
        panel = jnp.concatenate(parts, axis=0)
        if g > 1:
            # Device G·g+p assembled [strip p, p+1, … (group-relative,
            # wrapped)]; roll by its in-group rank restores global row
            # order so the panel is one contiguous window for every
            # group member.
            p = jax.lax.axis_index(axis) % g
            panel = jnp.roll(panel, p * n_loc, axis=0)
        iparts, cur = [panel], panel
        for _ in range(inter_hops):
            cur = jax.lax.ppermute(cur, axis, perm_inter)
            iparts.append(cur)
        return jnp.concatenate(iparts, axis=0) if inter_hops else panel

    fspec = P(axis, model_axis) if model_axis else P(axis)
    out_specs = (P(axis), P(axis)) if compact else P(axis)
    if mode == "self":
        def job2(feats_l, tiles_l):
            feats_g = _gather(feats_l)
            return _score(feats_g, feats_g, tiles_l)
        in_specs = (fspec, P(axis))
    elif mode == "cross":
        bspec = P(None, model_axis) if model_axis else P()

        def job2(feats_l, feats_q, tiles_l):
            feats_g = _gather(feats_l)
            return _score(feats_g, feats_q, tiles_l)
        in_specs = (fspec, bspec, P(axis))
    elif mode == "halo":
        def job2(feats_l, tiles_l):
            if halo:
                n_loc = feats_l.shape[0]
                k_hops = -(-halo // n_loc)
                take = halo - (k_hops - 1) * n_loc
                # Chained forward hops: before hop k each device holds
                # strip d+k−1 and forwards it; the LAST hop sends only
                # the ``take``-row prefix, so bytes received per device
                # are exactly halo · row_bytes.
                parts, cur = [feats_l], feats_l
                for k in range(1, k_hops + 1):
                    send = cur if k < k_hops else cur[:take]
                    cur = jax.lax.ppermute(send, axis, perm_fwd)
                    parts.append(cur)
                feats_cat = jnp.concatenate(parts, axis=0)
            else:
                feats_cat = feats_l
            return _score(feats_cat, feats_cat, tiles_l)
        in_specs = (fspec, P(axis))
    else:
        raise ValueError(f"unknown scorer mode {mode!r}")

    fn = jax.jit(_smap(job2, mesh, in_specs=in_specs, out_specs=out_specs))
    mask_factory = (
        (lambda: make_scorer(mesh, axis, mode=mode, threshold=threshold,
                             block_m=block_m, block_n=block_n, impl=impl,
                             halo=halo, compact=False, comms=comms,
                             hops=hops, group=group, inter_hops=inter_hops,
                             model_axis=model_axis))
        if compact else (lambda: None))
    return CatalogScorer(fn, compact=compact, capacity=cap,
                         mask_factory=mask_factory)


def _score_and_compact(shard, operands, tiles_dev, chunk: int,
                       bm: int, bn: int,
                       base_a: Optional[np.ndarray] = None,
                       base_b: Optional[np.ndarray] = None,
                       launch_flows=None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Drive a jitted per-shard catalog scorer chunk by chunk and compact
    each chunk's output into global (rows_a, rows_b) — host memory stays
    O(n_dev · chunk · bm · bn) regardless of plan size.

    Compact scorers (:class:`CatalogScorer` with ``compact=True``, the
    default on compiled backends) decode the kernel's packed survivor
    slots per device — O(survivors) host work, no ``np.nonzero``; a tile
    whose exact count exceeds the capacity (only possible with a
    user-bounded capacity) re-scores that chunk through the lazily built
    mask twin, exactness over speed. Both paths are counted in
    ``stage1_stats``. ``base_a``/``base_b`` (n_dev,) shift device-local
    tile coordinates to global rows on each side (the RepSN and
    ring/hierarchical local-coordinate paths — cross-mode ring shifts
    the a-side only, since the b operand was never rewritten); None
    means that side's tiles already carry global strip indices.
    ``launch_flows(chunk_size) -> {flow: bytes}`` is called once per
    scorer invocation (including mask-twin refires — every invocation
    re-runs its gather) and accumulated into
    ``stage1_stats["interconnect"]``."""
    cap = tiles_dev.shape[1]
    is_compact = getattr(shard, "compact", False)
    out_a, out_b = [], []

    def _account(csize: int) -> None:
        if launch_flows is None:
            return
        acc = stage1_stats["interconnect"]
        for k, v in launch_flows(csize).items():
            acc[k] = acc.get(k, 0) + v

    for lo in range(0, cap, chunk):
        part = tiles_dev[:, lo:lo + chunk]
        masks = None
        if is_compact:
            _account(part.shape[1])
            packed, counts = shard(*operands, jnp.asarray(part))
            counts = np.asarray(counts)[..., 0].astype(np.int64)  # (n_dev, C)
            if counts.max(initial=0) <= shard.capacity:
                stage1_stats["compact_decodes"] += 1
                packed = np.asarray(packed)
                for dd in range(part.shape[0]):
                    ra, rb = _decode_packed(packed[dd], counts[dd],
                                            part[dd], bm, bn)
                    off_a = base_a[dd] if base_a is not None else 0
                    off_b = base_b[dd] if base_b is not None else 0
                    out_a.append(off_a + ra)
                    out_b.append(off_b + rb)
                continue
            stage1_stats["compact_overflows"] += 1
            _account(part.shape[1])
            masks = np.asarray(shard.mask_twin()(*operands,
                                                 jnp.asarray(part)))
        if masks is None:
            _account(part.shape[1])
            masks = np.asarray(shard(*operands, jnp.asarray(part)))
        stage1_stats["nonzero_decodes"] += 1
        d, ti, ii, jj = np.nonzero(masks)
        off_a = base_a[d] if base_a is not None else 0
        off_b = base_b[d] if base_b is not None else 0
        out_a.append(off_a + part[d, ti, A_TILE].astype(np.int64) * bm + ii)
        out_b.append(off_b + part[d, ti, B_TILE].astype(np.int64) * bn + jj)
    if not out_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(out_a), np.concatenate(out_b)


def _tiles_by_device(catalog: TileCatalog, n_dev: int,
                     device_of: np.ndarray) -> np.ndarray:
    """(n_dev, cap, NCOLS) tile shards from an explicit placement (the
    comms planner's locality rule), zero-padded like
    :func:`tiles_for_devices` (empty windows mask everything out)."""
    counts = np.bincount(device_of, minlength=n_dev)
    cap = max(int(counts.max(initial=0)), 1)
    out = np.zeros((n_dev, cap, NCOLS), np.int32)
    for d in range(n_dev):
        mine = catalog.tiles[device_of == d]
        out[d, :mine.shape[0]] = mine
    return out


def _launch_flows_factory(plan: Optional[CommsPlan], halo: int,
                          n_data: int, n_model: int, n_rows: int,
                          feature_dim: int, bm: int, bn: int):
    """Per-launch interconnect accounting for :func:`_score_and_compact`:
    ``flows(chunk_size) -> {flow: bytes received per device}``, mirroring
    ``compiler.comms`` exactly (the gather/halo flows are launch-size
    independent; the psum payload is the launched tile count)."""
    if n_data <= 1 and n_model <= 1:
        return None
    n_loc = -(-n_rows // n_data)
    d_loc = feature_dim // max(n_model, 1)

    def flows(csize: int) -> dict:
        out = {}
        if halo:
            out["halo_bytes"] = sum(
                halo_bytes_per_device(n_loc, halo, d_loc))
        elif plan is not None and plan.policy == "ring":
            out["ring_bytes"] = plan.hops * n_loc * d_loc * plan.itemsize
        elif plan is not None and plan.policy == "hierarchical":
            row = d_loc * plan.itemsize
            out["hier_intra_bytes"] = (plan.group - 1) * n_loc * row
            out["hier_inter_bytes"] = (plan.inter_hops * plan.group
                                       * n_loc * row)
        elif n_data > 1:
            out["flat_bytes"] = (n_data - 1) * n_loc * d_loc * 4
        if n_model > 1:
            out["psum_bytes"] = psum_bytes_per_device(n_model, csize, bm, bn)
        return out

    return flows


def execute(catalog: TileCatalog, feats_a, feats_b=None, *,
            threshold: float, impl: str = "auto",
            mesh: Optional[Mesh] = None, axis: str = "data",
            chunk_tiles: int = 1024,
            schedule: Optional[Schedule] = None,
            healthy: Optional[np.ndarray] = None,
            scorer=None, fixed_chunks: bool = False,
            halo: int = 0, base: Optional[np.ndarray] = None,
            compact: bool = True,
            compact_capacity: Optional[int] = None,
            comms: str = "flat",
            comms_plan: Optional[CommsPlan] = None,
            model_axis: Optional[str] = None
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1 of ANY lowered catalog: compacted survivor candidates.

    Single host (``mesh=None``): chunked :func:`score_catalog` (comms
    and model_axis are mesh concepts and are ignored).
    On a mesh: tiles route to devices via the :class:`Schedule` (cost-LPT
    placement) or round-robin when none is given, and each device scores
    its shard through a :func:`make_scorer` data flow — "self" when
    ``feats_b`` is None, "cross" when it is given (b replicated), "halo"
    when ``halo > 0`` (RepSN boundary replication; implies self-join,
    ``base`` shifts local survivor coordinates to global rows; any
    window size — the scorer chains ⌈halo/n_loc⌉ hops).

    ``comms`` swaps the flat all-gather for the ring / hierarchical
    strip exchange: the plan (``comms_plan`` > ``schedule.comms`` >
    freshly planned from the catalog) carries the locality tile
    placement, hop counts and buffer origins; tiles are rewritten to
    buffer-local coordinates and the plan's ``base`` shifts survivors
    back (a-side only in cross mode). A plan that degraded to flat
    (``plan.fallback``) runs the flat path. Requires every device
    healthy — locality placement has no failover, degrade to flat for
    fault-tolerant runs. ``model_axis`` adds the second mesh axis:
    features column-sharded d/n_model, partial scores psum-combined
    in-scorer. Interconnect bytes per flow accumulate in
    ``stage1_stats["interconnect"]``.

    ``fixed_chunks=True`` pads every device shard UP to a ``chunk_tiles``
    multiple so each kernel launch has the exact shape (n_dev,
    chunk_tiles, NCOLS) — the resident service's recompile guard;
    the default shrinks the chunk to the shard cap for one-shot jobs.
    Pass ``scorer=`` to reuse a prebuilt :func:`make_scorer` (required
    for zero steady-state recompiles); with ``comms_plan`` the scorer's
    pinned hop count must cover the plan's (extra gathered strips are
    never referenced, so over-gathering is exact — just wasted bytes).

    Returns host int64 (rows_a, rows_b); run stage 2 via
    :func:`verify_pairs`.
    """
    if mesh is None:
        return score_catalog(feats_a, catalog, feats_b,
                             threshold=threshold, impl=impl,
                             chunk_tiles=chunk_tiles, compact=compact,
                             compact_capacity=compact_capacity)
    n_data = int(mesh.shape[axis])
    n_model = int(mesh.shape[model_axis]) if model_axis else 1
    bm, bn = catalog.block_m, catalog.block_n
    n_rows = int(feats_a.shape[0])
    feature_dim = int(feats_a.shape[1])

    plan = comms_plan
    if plan is None and schedule is not None:
        plan = getattr(schedule, "comms", None)
    if plan is None and comms != "flat":
        if halo:
            raise ValueError("halo mode has its own neighbor exchange; "
                             "comms must stay 'flat'")
        if healthy is not None and not bool(np.all(healthy)):
            raise ValueError("comms != 'flat' requires all devices healthy "
                             "(locality placement has no failover); run "
                             "degraded jobs with comms='flat'")
        plan = plan_comms(catalog, n_rows, n_data, policy=comms,
                          n_model=n_model, feature_dim=feature_dim,
                          self_join=feats_b is None)

    ring_like = plan is not None and plan.policy != "flat"
    if ring_like:
        tiles_dev = _tiles_by_device(catalog, n_data, plan.device_of_tile)
    else:
        tiles_dev = tiles_for_devices(catalog, n_data, healthy, schedule)
    if fixed_chunks:
        chunk = chunk_tiles
    else:
        chunk = min(chunk_tiles, max(tiles_dev.shape[1], 1))
    tiles_dev = pad_tiles(tiles_dev, chunk)
    base_a = base_b = base
    if ring_like:
        tiles_dev = rewrite_tiles_local(tiles_dev, plan.base, bm, bn,
                                        shift_b=feats_b is None)
        base_a = plan.base
        base_b = plan.base if feats_b is None else None
    if scorer is None:
        mode = "halo" if halo > 0 else ("cross" if feats_b is not None
                                        else "self")
        rimpl = _resolve_impl(impl)
        scorer = make_scorer(mesh, axis, mode=mode, threshold=threshold,
                             block_m=bm, block_n=bn, impl=rimpl, halo=halo,
                             compact=compact and _compact_on_device(rimpl),
                             capacity=compact_capacity,
                             comms=plan.policy if plan is not None else "flat",
                             hops=plan.hops if plan is not None else 0,
                             group=plan.group if plan is not None else 1,
                             inter_hops=(plan.inter_hops
                                         if plan is not None else 0),
                             model_axis=model_axis)
    operands = ((feats_a,) if feats_b is None
                else (feats_a, jnp.asarray(feats_b)))
    flows = _launch_flows_factory(plan, halo, n_data, n_model, n_rows,
                                  feature_dim, bm, bn)
    return _score_and_compact(scorer, operands, tiles_dev, chunk, bm, bn,
                              base_a=base_a, base_b=base_b,
                              launch_flows=flows)


# ---------------------------------------------------------------------------
# Supervised stage 1: tile-granular fault recovery
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardRecord:
    """One per-device-shard completion record, the supervisor's ledger."""
    round: int
    device: int
    tiles: int
    cost: int                  # live pairs the shard was responsible for
    status: str                # ok | killed | transient | timeout | corrupt
    elapsed: float             # REAL wall seconds of the shard call
    injected_delay: float = 0.0  # virtual straggle seconds (injector only)

    @property
    def busy(self) -> float:
        """Simulated device-busy seconds: real wall time plus the
        injected virtual delay. Deadlines, the makespan clock and the
        feedback model run on busy time; latency *statistics* must use
        ``elapsed`` so chaos scripts don't poison them."""
        return self.elapsed + self.injected_delay


@dataclass
class SupervisedReport:
    """What happened during one :func:`execute_supervised` run."""
    rounds: int = 0            # scheduling rounds executed (1 == quiet run)
    recovered_tiles: int = 0   # tiles that succeeded on a retry round
    planned_cost: int = 0      # live pairs the catalog plans
    scored_cost: int = 0       # live pairs covered by accepted shards
    lost_tiles: int = 0        # tiles never scored (degraded mode only)
    steals: int = 0            # mid-stream re-LPT events (slow devices)
    stolen_tiles: int = 0      # queued tiles moved off slow devices
    predicted_makespan_s: float = 0.0  # calibrated round-1 projection
    measured_makespan_s: float = 0.0   # Σ rounds max device busy-time
    records: List[ShardRecord] = field(default_factory=list)
    backoffs: List[float] = field(default_factory=list)
    healthy: Optional[np.ndarray] = None   # final device mask

    @property
    def retries(self) -> int:
        return max(self.rounds - 1, 0)

    @property
    def coverage(self) -> float:
        """Fraction of planned live pairs actually scored — 1.0 after a
        full recovery, < 1.0 only in degraded (partial) mode."""
        if self.planned_cost == 0:
            return 1.0
        return self.scored_cost / self.planned_cost


class RecoveryFailedError(RuntimeError):
    """Retries/deadline exhausted with tiles still unscored (and the
    caller did not opt into partial results). Carries the report."""

    def __init__(self, msg: str, report: SupervisedReport):
        super().__init__(msg)
        self.report = report


def shard_sane(rows_a: np.ndarray, rows_b: np.ndarray,
               n_a: int, n_b: int) -> bool:
    """Cheap survivor sanity check: paired 1-D int arrays, every index in
    bounds. Any corrupted shard from :meth:`FaultInjector.corrupt_output`
    fails this by construction; a real deployment would run the same
    check on rows coming back over the wire."""
    if rows_a.shape != rows_b.shape or rows_a.ndim != 1:
        return False
    if rows_a.size == 0:
        return True
    return bool((rows_a >= 0).all() and (rows_a < n_a).all()
                and (rows_b >= 0).all() and (rows_b < n_b).all())


def _sub_catalog(catalog: TileCatalog, idx: np.ndarray) -> TileCatalog:
    return TileCatalog(tiles=catalog.tiles[idx], block_m=catalog.block_m,
                       block_n=catalog.block_n, n_rows_a=catalog.n_rows_a,
                       n_rows_b=catalog.n_rows_b, r=catalog.r,
                       total_pairs=catalog.total_pairs)


def execute_supervised(catalog: TileCatalog, feats_a, feats_b=None, *,
                       threshold: float, n_dev: int = 1,
                       healthy: Optional[np.ndarray] = None,
                       impl: str = "auto", chunk_tiles: int = 1024,
                       policy: str = "cost_lpt",
                       injector: Optional[FaultInjector] = None,
                       shard_deadline: Optional[float] = None,
                       deadline: Optional[float] = None,
                       max_retries: int = 3, backoff: float = 0.05,
                       backoff_factor: float = 2.0, sleep=time.sleep,
                       partial: bool = False,
                       feedback: Optional[EwmaCostModel] = None,
                       steal_factor: Optional[float] = None,
                       steal_quantum: Optional[int] = None,
                       compact: bool = True,
                       compact_capacity: Optional[int] = None
                       ) -> Tuple[np.ndarray, np.ndarray, SupervisedReport]:
    """Stage 1 with tile-granular fault recovery over logical devices.

    The catalog's tiles are cost-LPT scheduled onto ``n_dev`` logical
    device shards (the host drives each shard through the kernel exactly
    as ``execute`` would on a mesh — on a real cluster each shard call
    is the per-device RPC). Every shard produces a completion record;
    shards that fail (killed device), time out (wall + injected latency
    > ``shard_deadline``), raise transiently, or return survivors that
    fail :func:`shard_sane` are DISCARDED, their device is masked out
    where the failure indicates device loss (kill/timeout), and ONLY the
    lost tiles are re-scheduled over the shrunken healthy mask — at most
    ``max_retries`` extra rounds with exponential backoff
    (``backoff * backoff_factor**k``, each sleep clamped to the
    remaining wall ``deadline``).

    **Runtime feedback.** Pass ``feedback=`` (an :class:`EwmaCostModel`)
    and every accepted shard call trains the model, and every round's
    ``schedule_tiles`` is calibrated by it (wall-clock-weighted tile
    packing, heterogeneous device placement). Pass ``steal_factor=`` to
    enable mid-stream work stealing: each device's round work is split
    into ``steal_quantum``-tile batches (one batch per device when
    unset), dispatch follows per-device virtual busy-time clocks (the
    idle-device-next simulation of a parallel fleet), and after every
    completed call a device whose projected finish exceeds
    ``steal_factor ×`` the fleet's median projection has its *queued*
    (never in-flight) batches re-placed greedily onto the
    fastest-projected other devices. Stolen tiles were not yet scored,
    so exactly-once merging is untouched.

    Survivors merge idempotently: the catalog covers each planned pair
    exactly once and results from failed shards are never merged, so
    re-executing a tile cannot double-count — the final
    ``np.unique`` over (row_a, row_b) makes recovery exactly-once at the
    match-set level even if a future policy merges late stragglers.

    ``deadline`` bounds the whole call (seconds); on exhaustion —
    or when retries run out, or every device dies — the call either
    raises :class:`RecoveryFailedError` / :class:`NoHealthyDevicesError`
    or, with ``partial=True``, returns what it has with
    ``report.coverage < 1`` (the service's graceful-degradation mode).

    Returns ``(rows_a, rows_b, report)`` — deduplicated host int64
    survivor candidates plus the :class:`SupervisedReport`.
    """
    t_start = time.perf_counter()
    if healthy is None:
        healthy = np.ones(n_dev, bool)
    healthy = np.asarray(healthy, bool).copy()
    if steal_factor is not None and feedback is None:
        feedback = EwmaCostModel(n_dev)
    costs = tile_costs(catalog)
    classes = tile_class(catalog) if feedback is not None else None
    report = SupervisedReport(planned_cost=int(costs.sum()), healthy=healthy)
    out_a: List[np.ndarray] = [np.zeros(0, np.int64)]
    out_b: List[np.ndarray] = [np.zeros(0, np.int64)]
    pending = np.arange(catalog.num_tiles, dtype=np.int64)
    n_a, n_b = catalog.n_rows_a, catalog.n_rows_b

    def _out_of_time() -> bool:
        return (deadline is not None
                and time.perf_counter() - t_start >= deadline)

    def _predict(dev: int, batch: np.ndarray) -> float:
        return feedback.predict_tiles(dev, costs[batch], classes[batch])

    def _steal_pass(queues, clocks) -> None:
        """Re-place every queued batch of over-projected devices onto the
        fastest-projected peers (greedy, largest batch first)."""
        proj = {}
        for k in clocks:
            proj[k] = clocks[k] + sum(_predict(k, b)
                                      for b in queues.get(k, ()))
        med = float(np.median(list(proj.values())))
        victims = [k for k in list(queues)
                   if proj[k] > steal_factor * max(med, 1e-9)]
        for v in victims:
            if len(clocks) < 2:
                return
            batches = queues.pop(v)
            report.steals += 1
            proj[v] = clocks[v]
            batches.sort(key=lambda b: -float(costs[b].sum()))
            for b in batches:
                dst = min((k for k in clocks if k != v),
                          key=lambda k: (proj[k] + _predict(k, b), k))
                queues.setdefault(dst, []).append(b)
                proj[dst] += _predict(dst, b)
                report.stolen_tiles += int(b.size)

    while pending.size:
        if report.rounds > max_retries or _out_of_time():
            break
        if report.rounds:                       # retry round: back off
            b = backoff * backoff_factor ** (report.rounds - 1)
            if deadline is not None:            # never sleep past deadline
                b = min(b, max(deadline - (time.perf_counter() - t_start),
                               0.0))
            report.backoffs.append(b)
            if b > 0:
                sleep(b)
            if _out_of_time():                  # re-check: sleep spent it
                break
        report.rounds += 1
        sub = _sub_catalog(catalog, pending)
        try:
            sched = schedule_tiles(sub, n_dev=n_dev, healthy=healthy,
                                   policy=policy, feedback=feedback)
        except NoHealthyDevicesError:
            if partial:
                break
            report.lost_tiles = int(pending.size)
            raise
        if report.rounds == 1 and sched.calibrated:
            report.predicted_makespan_s = float(np.max(sched.predicted_s))
        dev_of_tile = sched.reducer_device[sched.tile_reducer]
        lost: List[np.ndarray] = []
        # Per-device FIFO queues of quantum-sized batches plus virtual
        # busy-time clocks; dispatching to the min-clock device (lowest
        # id on ties) simulates a parallel fleet — with one batch per
        # device and zeroed clocks it reproduces the classic ascending-
        # device-order call sequence exactly.
        queues: dict = {}
        clocks: dict = {}
        for d in np.flatnonzero(healthy):
            d = int(d)
            clocks[d] = 0.0
            mine = pending[dev_of_tile == d]
            if mine.size == 0:
                continue
            if steal_quantum:
                queues[d] = [mine[lo:lo + steal_quantum]
                             for lo in range(0, mine.size, steal_quantum)]
            else:
                queues[d] = [mine]
        round_makespan = 0.0
        while queues:
            if _out_of_time():
                for q in queues.values():
                    lost.extend(q)
                queues.clear()
                break
            d = min(queues, key=lambda k: (clocks[k], k))
            mine = queues[d].pop(0)
            if not queues[d]:
                del queues[d]
            cost = int(costs[mine].sum())
            t0 = time.perf_counter()
            status, extra = "ok", 0.0
            ra = rb = None
            try:
                plan = injector.shard_call(d) if injector else None
                ra, rb = score_catalog(
                    feats_a, _sub_catalog(catalog, mine), feats_b,
                    threshold=threshold, impl=impl,
                    chunk_tiles=chunk_tiles, compact=compact,
                    compact_capacity=compact_capacity)
                if plan is not None:
                    extra = plan.delay
                    if plan.corrupt:
                        ra, rb = injector.corrupt_output(ra, rb, n_a, n_b)
            except DeviceKilledError:
                status = "killed"
            except TransientScorerError:
                status = "transient"
            elapsed = time.perf_counter() - t0
            busy = elapsed + extra
            if status == "ok":
                if shard_deadline is not None and busy > shard_deadline:
                    status = "timeout"          # straggler: discard output
                elif not shard_sane(ra, rb, n_a, n_b):
                    status = "corrupt"          # failed the sanity check
            report.records.append(ShardRecord(
                round=report.rounds, device=d, tiles=int(mine.size),
                cost=cost, status=status, elapsed=elapsed,
                injected_delay=extra))
            clocks[d] += busy
            round_makespan = max(round_makespan, clocks[d])
            if status == "ok":
                out_a.append(ra)
                out_b.append(rb)
                report.scored_cost += cost
                if report.rounds > 1:
                    report.recovered_tiles += int(mine.size)
                if feedback is not None and cost > 0:
                    feedback.observe(
                        d, np.bincount(classes[mine], weights=costs[mine],
                                       minlength=N_TILE_CLASSES), busy)
            else:
                lost.append(mine)
                if status in ("killed", "timeout"):
                    healthy[d] = False          # device-level failure
                    lost.extend(queues.pop(d, []))
                    clocks.pop(d, None)
            if (steal_factor is not None and feedback is not None
                    and feedback.observations >= 1
                    and queues and len(clocks) > 1):
                _steal_pass(queues, clocks)
        report.measured_makespan_s += round_makespan
        pending = (np.concatenate(lost) if lost
                   else np.zeros(0, np.int64))

    report.lost_tiles = int(pending.size)
    report.healthy = healthy
    if pending.size and not partial:
        raise RecoveryFailedError(
            f"{pending.size} tiles unscored after {report.retries} retries",
            report)
    ra = np.concatenate(out_a)
    rb = np.concatenate(out_b)
    if ra.size:                                 # exactly-once at the
        pairs = np.unique(np.stack([ra, rb], axis=1), axis=0)   # match level
        ra, rb = pairs[:, 0], pairs[:, 1]
    return ra, rb, report


# ---------------------------------------------------------------------------
# Stage 2 + the fused entry point
# ---------------------------------------------------------------------------

_VERIFY_CHUNK = 8_192


def verify_pairs(codes_a, lens_a, codes_b, lens_b, rows_a, rows_b,
                 threshold: float,
                 chunk: int = _VERIFY_CHUNK) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 2: exact normalized edit similarity >= threshold on candidate
    row pairs, in fixed-size padded chunks (one jit compilation)."""
    from ..similarity import edit_similarity

    hit_a, hit_b = [], []
    for lo in range(0, rows_a.shape[0], chunk):
        a = rows_a[lo:lo + chunk]
        b = rows_b[lo:lo + chunk]
        pad = chunk - a.shape[0]
        if pad:
            a = np.concatenate([a, np.zeros(pad, a.dtype)])
            b = np.concatenate([b, np.zeros(pad, b.dtype)])
        sim = np.array(edit_similarity(
            codes_a[a], lens_a[a], codes_b[b], lens_b[b]))
        if pad:
            sim[chunk - pad:] = 0.0
        sel = np.flatnonzero(sim >= threshold)
        hit_a.append(a[sel])
        hit_b.append(b[sel])
    if not hit_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(hit_a), np.concatenate(hit_b)


def match_catalog(catalog: TileCatalog, feats_a, codes_a, lens_a, *,
                  feats_b=None, codes_b=None, lens_b=None,
                  threshold: float = 0.8, filter_margin: float = 0.25,
                  impl: str = "auto", mesh: Optional[Mesh] = None,
                  axis: str = "data", schedule: Optional[Schedule] = None,
                  chunk_tiles: int = 1024,
                  compact_capacity: Optional[int] = None,
                  comms: str = "flat",
                  comms_plan: Optional[CommsPlan] = None,
                  model_axis: Optional[str] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Fused filter-and-verify: kernel stage 1 over the tile catalog,
    exact stage 2 on compacted survivors. Returns matched (rows_a, rows_b)
    — indices into the a-side (and b-side, if distinct) arrays.
    ``comms``/``comms_plan``/``model_axis`` pass through to
    :func:`execute` (mesh runs only)."""
    cand_a, cand_b = execute(
        catalog, feats_a, feats_b,
        threshold=threshold - filter_margin, impl=impl,
        mesh=mesh, axis=axis, schedule=schedule, chunk_tiles=chunk_tiles,
        compact_capacity=compact_capacity, comms=comms,
        comms_plan=comms_plan, model_axis=model_axis)
    if codes_b is None:
        codes_b, lens_b = codes_a, lens_a
    return verify_pairs(codes_a, lens_a, codes_b, lens_b,
                        cand_a, cand_b, threshold)
