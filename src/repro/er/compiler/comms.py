"""Interconnect planning for the 2-D (``data`` × ``model``) mesh executor.

The flat executor all_gathers the whole a-side feature matrix onto every
device — bytes received per device grow O(n) regardless of what the
device's tiles actually read. This module plans the two cheaper gather
policies (DESIGN.md §Mesh scale-out) and accounts every data flow's
wire bytes exactly:

  * **ring** — *locality placement*: every tile lands on the device that
    owns its MINIMUM needed strip (strip = contiguous n_loc-row shard),
    so all strips a device needs are strictly *forward* of its own and
    the global hop count H = max over tiles of (max strip − min strip).
    H chained ``ppermute`` hops assemble, per device, the contiguous
    global row window [d·n_loc, d·n_loc + (H+1)·n_loc) — bytes received
    drop from O(n) to O(n_loc · H). For blocked ER plans tiles live
    inside block rectangles, so H is small while a flat gather still
    pays n − n_loc.
  * **hierarchical** — devices form groups of g consecutive strips:
    an intra-group ring (g − 1 hops of n_loc rows) assembles each
    group's panel, then Hg inter-group hops at stride g exchange whole
    g·n_loc-row panels. Same locality argument one level up (group =
    min needed strip's group; within the group the g members are free,
    so tiles LPT-balance across them — the placement freedom ring gives
    up). Bytes: (g−1)·n_loc + Hg·g·n_loc rows per device.
  * **psum** (model axis) — features column-sharded d/n_model per
    device; per-tile partial scores combine with one psum over
    ``model``. A ring all-reduce of a P-byte payload receives
    2·(n_model−1)/n_model · P bytes per device.
  * **halo** (RepSN) — ⌈halo/n_loc⌉ chained neighbor hops, the last hop
    sending only the final partial strip, so received bytes are exactly
    halo · row_bytes per device (see ``halo_hop_rows``).

Every formula here is the single source of truth: the executor records
the same numbers into ``stage1_stats["interconnect"]`` and
``Schedule.stats()`` surfaces them via the plan, and the mesh benchmark
asserts the ring/flat ratio they predict.

The local-coordinate contract: ring/hierarchical buffers are contiguous
global row windows starting at ``base[dev]``, so tiles rewrite to buffer
coordinates by a uniform shift (``rewrite_tiles_local``) — which is only
exact when n_loc is a multiple of the tile geometry. ``plan_comms``
degrades to flat (with ``fallback`` naming the reason) whenever the
preconditions fail, so callers never have to pre-validate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from .ir import (A_TILE, B_TILE, R0, R1, C0, C1, LB_R, LB_C, UB_R, UB_C,
                 BAND, TileCatalog)

__all__ = [
    "COMMS_POLICIES",
    "CommsPlan",
    "plan_comms",
    "comms_volume",
    "rewrite_tiles_local",
    "halo_hop_rows",
    "halo_bytes_per_device",
    "psum_bytes_per_device",
    "default_group",
]

COMMS_POLICIES = ("flat", "ring", "hierarchical")


def default_group(n_data: int) -> int:
    """Largest divisor of n_data that is <= sqrt(n_data) — the balanced
    two-level split (16 → 4×4, 8 → 2×4, primes → 1, i.e. degenerate)."""
    g = 1
    for cand in range(1, int(np.sqrt(n_data)) + 1):
        if n_data % cand == 0:
            g = cand
    return g


def halo_hop_rows(n_loc: int, halo: int) -> List[int]:
    """Rows received per hop of the multi-hop halo chain: full strips for
    every hop but the last, which sends only the final partial strip —
    the schedule the executor implements, summing to exactly ``halo``."""
    if halo <= 0:
        return []
    hops = -(-halo // n_loc)
    take = halo - (hops - 1) * n_loc
    return [n_loc] * (hops - 1) + [take]


def halo_bytes_per_device(n_loc: int, halo: int, feature_dim: int,
                          itemsize: int = 4) -> List[int]:
    """Per-hop bytes received per device for the RepSN halo exchange."""
    return [r * feature_dim * itemsize for r in halo_hop_rows(n_loc, halo)]


def psum_bytes_per_device(n_model: int, num_tiles: int, block_m: int,
                          block_n: int, itemsize: int = 4) -> int:
    """Bytes received per device by the model-axis psum of ``num_tiles``
    partial-score tiles (ring all-reduce accounting: each device receives
    2·(n_model−1)/n_model of the f32 payload)."""
    if n_model <= 1:
        return 0
    payload = num_tiles * block_m * block_n * itemsize
    return int(2 * (n_model - 1) * payload // n_model)


@dataclass(frozen=True)
class CommsPlan:
    """A resolved gather policy for one catalog on one mesh geometry:
    the locality tile placement, the hop counts the scorer must compile
    with, the buffer origins that shift local survivor coordinates back
    to global rows, and the exact per-flow byte accounting."""
    policy: str                    # resolved: flat | ring | hierarchical
    requested: str                 # what the caller asked for
    n_data: int
    n_model: int
    n_loc: int                     # a-side rows per data shard
    hops: int                      # ring: chained ppermute hops
    group: int                     # hierarchical: devices per group (g)
    inter_hops: int                # hierarchical: group-panel hops
    self_join: bool
    feature_dim: int
    device_of_tile: Optional[np.ndarray] = None   # (T,) locality placement
    base: Optional[np.ndarray] = None             # (n_data,) buffer origins
    itemsize: int = 4
    fallback: Optional[str] = None  # why the request degraded to flat

    @property
    def buffer_rows(self) -> int:
        """Rows of the assembled per-device feature buffer."""
        if self.policy == "ring":
            return (self.hops + 1) * self.n_loc
        if self.policy == "hierarchical":
            return (self.inter_hops + 1) * self.group * self.n_loc
        return self.n_loc * self.n_data

    def bytes_received_per_device(self) -> Dict[str, int]:
        """Exact interconnect bytes RECEIVED per device, per flow.

        ``psum`` is omitted here (it depends on launched tile counts —
        the executor records it into ``stage1_stats`` exactly); the
        gather flows are pure functions of the plan."""
        row = (self.feature_dim // max(self.n_model, 1)) * self.itemsize
        if self.n_data <= 1:
            return {"total": 0}
        if self.policy == "ring":
            out = {"ring_hop": self.n_loc * row,
                   "ring": self.hops * self.n_loc * row}
            out["total"] = out["ring"]
            return out
        if self.policy == "hierarchical":
            intra = (self.group - 1) * self.n_loc * row
            inter = self.inter_hops * self.group * self.n_loc * row
            return {"hier_intra": intra, "hier_inter": inter,
                    "total": intra + inter}
        flat = (self.n_data - 1) * self.n_loc * row
        return {"flat_gather": flat, "total": flat}

    def summary(self) -> Dict:
        """JSON-able plan report (lands on ``Schedule.stats()``)."""
        out = {
            "policy": self.policy,
            "requested": self.requested,
            "n_data": self.n_data,
            "n_model": self.n_model,
            "n_loc": self.n_loc,
            "hops": self.hops,
            "group": self.group,
            "inter_hops": self.inter_hops,
            "buffer_rows": self.buffer_rows,
            "bytes_received_per_device": self.bytes_received_per_device(),
        }
        if self.fallback:
            out["fallback"] = self.fallback
        return out


def _tile_row_spans(tiles: np.ndarray, bm: int, bn: int,
                    self_join: bool) -> tuple:
    """(lo, hi, live) — the a-side feature rows each tile actually reads:
    its row window clipped to the tile, unioned (self-join) with its
    column window, since self-join columns index the same matrix.
    ``hi`` is exclusive; dead tiles (empty windows) report (0, 0)."""
    t = tiles.astype(np.int64)
    a_lo = np.maximum(t[:, R0], t[:, A_TILE] * bm)
    a_hi = np.minimum(t[:, R1], (t[:, A_TILE] + 1) * bm)
    live = a_hi > a_lo
    lo, hi = a_lo, a_hi
    if self_join:
        b_lo = np.maximum(t[:, C0], t[:, B_TILE] * bn)
        b_hi = np.minimum(t[:, C1], (t[:, B_TILE] + 1) * bn)
        live = live & (b_hi > b_lo)
        lo = np.minimum(lo, b_lo)
        hi = np.maximum(hi, b_hi)
    lo = np.where(live, lo, 0)
    hi = np.where(live, hi, 0)
    return lo, hi, live


def _flat(requested: str, n_data: int, n_model: int, n_loc: int,
          self_join: bool, feature_dim: int, itemsize: int,
          reason: Optional[str]) -> CommsPlan:
    return CommsPlan(policy="flat", requested=requested, n_data=n_data,
                     n_model=n_model, n_loc=n_loc, hops=0, group=1,
                     inter_hops=0, self_join=self_join,
                     feature_dim=feature_dim, itemsize=itemsize,
                     fallback=reason)


def plan_comms(catalog: TileCatalog, n_rows: int, n_data: int, *,
               policy: str = "ring", n_model: int = 1,
               feature_dim: int, self_join: bool = True,
               group: Optional[int] = None, itemsize: int = 4,
               pin_hops: Optional[int] = None,
               pin_inter_hops: Optional[int] = None) -> CommsPlan:
    """Resolve a gather policy for ``catalog`` over ``n_data`` shards of
    an ``n_rows``-row a-side feature matrix (the *sharded* length —
    including any residency padding, which tiles never reference).

    Placement is locality-first: each tile goes to the owner of its
    minimum needed strip (ring) or to an LPT-balanced member of that
    strip's group (hierarchical), which is what bounds the hop count.
    ``pin_hops`` / ``pin_inter_hops`` freeze the compiled hop count (the
    resident service's zero-recompile contract): plans whose tiles need
    more hops than the pin degrade to flat instead of recompiling.

    Degrades to ``policy="flat"`` — with ``fallback`` naming the reason
    — whenever the local-coordinate rewrite cannot be exact: n_rows not
    shard-divisible, n_loc not a multiple of the tile geometry, or a
    banded self-join rewrite that a cross-side shift would skew.
    """
    if policy not in COMMS_POLICIES:
        raise ValueError(f"unknown comms policy {policy!r}")
    if feature_dim % max(n_model, 1):
        raise ValueError(
            f"feature_dim={feature_dim} not divisible by n_model={n_model}")
    n_loc = n_rows // n_data if n_data else n_rows
    if policy == "flat" or n_data <= 1:
        return _flat(policy, n_data, n_model, n_loc, self_join,
                     feature_dim, itemsize, None)
    bm, bn = catalog.block_m, catalog.block_n
    if n_rows % n_data:
        return _flat(policy, n_data, n_model, n_loc, self_join, feature_dim,
                     itemsize, f"n_rows={n_rows} not divisible by "
                               f"n_data={n_data}")
    if n_loc % bm or (self_join and n_loc % bn):
        return _flat(policy, n_data, n_model, n_loc, self_join, feature_dim,
                     itemsize, f"n_loc={n_loc} not a multiple of the tile "
                               f"geometry ({bm}, {bn})")
    if not self_join and (catalog.tiles[:, BAND] > 0).any():
        # A banded predicate compares col − row; a cross-mode rewrite
        # shifts rows only, which would skew the band.
        return _flat(policy, n_data, n_model, n_loc, self_join, feature_dim,
                     itemsize, "banded tiles in cross mode")

    lo, hi, live = _tile_row_spans(catalog.tiles, bm, bn, self_join)
    s_min = np.where(live, lo // n_loc, 0)
    s_max = np.where(live, np.maximum(hi - 1, 0) // n_loc, 0)

    if policy == "ring":
        hops = int((s_max - s_min).max(initial=0))
        if pin_hops is not None:
            if hops > pin_hops:
                return _flat(policy, n_data, n_model, n_loc, self_join,
                             feature_dim, itemsize,
                             f"tile span needs {hops} hops > pinned "
                             f"{pin_hops}")
            hops = pin_hops
        return CommsPlan(policy="ring", requested=policy, n_data=n_data,
                         n_model=n_model, n_loc=n_loc, hops=hops, group=1,
                         inter_hops=0, self_join=self_join,
                         feature_dim=feature_dim,
                         device_of_tile=s_min.astype(np.int64),
                         base=np.arange(n_data, dtype=np.int64) * n_loc,
                         itemsize=itemsize)

    g = group if group is not None else default_group(n_data)
    if g < 1 or n_data % g:
        raise ValueError(f"group={g} does not divide n_data={n_data}")
    g_min = s_min // g
    g_max = s_max // g
    inter = int((g_max - g_min).max(initial=0))
    if pin_inter_hops is not None:
        if inter > pin_inter_hops:
            return _flat(policy, n_data, n_model, n_loc, self_join,
                         feature_dim, itemsize,
                         f"tile span needs {inter} group hops > pinned "
                         f"{pin_inter_hops}")
        inter = pin_inter_hops
    # Within each group the g members all hold the same buffer, so
    # placement is free — LPT-balance by exact tile cost.
    from .schedule import tile_costs
    costs = tile_costs(catalog)
    device_of = np.zeros(catalog.num_tiles, np.int64)
    for grp in np.unique(g_min):
        mine = np.flatnonzero(g_min == grp)
        order = mine[np.argsort(-costs[mine], kind="stable")]
        load = np.zeros(g, np.int64)
        for ti in order:
            d = int(load.argmin())
            device_of[ti] = grp * g + d
            load[d] += costs[ti]
    base = (np.arange(n_data, dtype=np.int64) // g) * g * n_loc
    return CommsPlan(policy="hierarchical", requested=policy, n_data=n_data,
                     n_model=n_model, n_loc=n_loc, hops=0, group=g,
                     inter_hops=inter, self_join=self_join,
                     feature_dim=feature_dim, device_of_tile=device_of,
                     base=base, itemsize=itemsize)


def comms_volume(catalog: TileCatalog, n_rows: int, n_dev: int, *,
                 feature_dim: int, self_join: bool = True,
                 group: Optional[int] = None,
                 itemsize: int = 4) -> Dict[str, int]:
    """Model-only per-device byte table for a scaling sweep: the bytes
    each policy WOULD receive per device at ``n_dev`` shards, with no
    executor preconditions (strips are ⌈n/n_dev⌉ rows; geometry
    divisibility is irrelevant to the accounting). Used by the fig13
    sweep; ``plan_comms`` is the executor's exact sibling."""
    n_loc = max(-(-n_rows // n_dev), 1)
    row = feature_dim * itemsize
    if n_dev <= 1:
        return {"flat_gather": 0, "ring": 0, "hier_intra": 0,
                "hier_inter": 0, "ring_hops": 0, "hier_inter_hops": 0}
    lo, hi, live = _tile_row_spans(catalog.tiles, catalog.block_m,
                                   catalog.block_n, self_join)
    s_min = np.where(live, lo // n_loc, 0)
    s_max = np.where(live, np.maximum(hi - 1, 0) // n_loc, 0)
    hops = int((s_max - s_min).max(initial=0))
    g = group if group is not None else default_group(n_dev)
    inter = int((s_max // g - s_min // g).max(initial=0)) if g else 0
    return {
        "flat_gather": (n_dev - 1) * n_loc * row,
        "ring": hops * n_loc * row,
        "hier_intra": (g - 1) * n_loc * row,
        "hier_inter": inter * g * n_loc * row,
        "ring_hops": hops,
        "hier_inter_hops": inter,
    }


def rewrite_tiles_local(tiles_dev: np.ndarray, base: np.ndarray,
                        bm: int, bn: int,
                        shift_b: bool = True) -> np.ndarray:
    """Shift per-device tiles from global to buffer-local coordinates.

    Device d's assembled buffer is the contiguous global row window
    starting at ``base[d]``, so the rewrite is a uniform translation:
    row coordinates (A_TILE, R0, R1, LB_R, UB_R) drop base[d] (A_TILE in
    units of bm); with ``shift_b`` (self-join — columns index the same
    buffer) the column coordinates (B_TILE, C0, C1, LB_C, UB_C) drop it
    too. Every catalog predicate is a translation-invariant comparison
    (the band needs BOTH sides shifted — cross mode must not carry
    bands, which ``plan_comms`` guarantees); the NO_LB/NO_UB sentinels
    shift to equally-inert values. All-zero padding entries (empty
    windows) stay untouched so their tile indices remain in range."""
    b64 = np.asarray(base, np.int64)
    if (b64 % bm).any() or (shift_b and (b64 % bn).any()):
        raise ValueError("buffer origins must be tile-aligned")
    out = tiles_dev.astype(np.int64, copy=True)
    live = out[:, :, R1] > out[:, :, R0]
    b = b64[:, None]
    for col, unit in ((A_TILE, bm), (R0, 1), (R1, 1), (LB_R, 1), (UB_R, 1)):
        out[:, :, col] = np.where(live, out[:, :, col] - b // unit,
                                  out[:, :, col])
    if shift_b:
        for col, unit in ((B_TILE, bn), (C0, 1), (C1, 1), (LB_C, 1),
                          (UB_C, 1)):
            out[:, :, col] = np.where(live, out[:, :, col] - b // unit,
                                      out[:, :, col])
    return out.astype(np.int32)
