"""Unified match-job compiler: one plan → catalog → schedule → execute
pipeline (DESIGN.md §Compiler).

Every strategy — Basic / BlockSplit / PairRange self-joins, the Sorted
Neighborhood band, two-source R × S query jobs and the match_⊥ cross
jobs — flows through the same four stages:

  1. **Plan IR** (`ir.py`): the planner's output lowers into a
     :class:`MatchJob` — a flat table of corner-cut task rectangles over
     the blocked feature layout(s). `plan_to_job` is the only
     strategy-aware code in the whole execution stack.
  2. **Lowering** (`lower.py`): `lower(job) -> TileCatalog` tiles every
     task into MXU-aligned catalog entries — the single implementation
     behind what used to be six per-strategy `catalog_for_*` builders.
  3. **Scheduling** (`schedule.py`): an exact per-tile cost model (the
     live masked-pair count under the tile's predicates) feeds
     `core.assignment.greedy_lpt` to assign tiles → reducers → devices;
     `Schedule.stats()` reports the imbalance the paper optimizes.
  4. **Execution** (`execute.py`): one generic `execute(catalog,
     feats_*, mesh=...)` scores any catalog — single host, all-gather
     self-join, replicated-query cross join, or RepSN multi-hop halo
     exchange — through the fused kernel, replacing the per-strategy
     shard_map wrappers. A `comms=` policy (`comms.py`) swaps the flat
     all-gather for ring / hierarchical strip exchanges on the `data`
     axis, and `model_axis=` column-shards the features over a second
     mesh axis with in-scorer psum combination; every flow's
     bytes-received-per-device lands in `stage1_stats["interconnect"]`
     and on `Schedule.stats()`.

A fifth, optional layer wraps execution in a fault-tolerant supervisor
(`execute_supervised` + `faults.py`, DESIGN.md §Fault tolerance):
deterministic seeded fault injection (device kills, stragglers,
transient scorer errors, corrupted survivor output), per-device-shard
completion records, and tile-granular recovery — lost tiles are
re-scheduled over the shrunken healthy mask with bounded exponential
backoff, and survivors merge exactly-once at the match-set level.

A sixth closes the loop (`feedback.py`, DESIGN.md §Scheduling): an
EWMA model of measured seconds-per-live-pair per (device, tile class)
calibrates `schedule_tiles` and drives mid-stream work stealing in the
supervisor — slow devices' queued tiles are re-placed onto
faster-projected peers before the round ends.

`er/executor.py` and `er/distributed.py` keep their historical entry
points as thin shims over this package.
"""
from .ir import (  # noqa: F401
    A_TILE, B_TILE, R0, R1, C0, C1, TRI, LB_R, LB_C, UB_R, UB_C, BAND, RED,
    NCOLS,
    MatchJob,
    TileCatalog,
    cross_job,
    make_job,
    plan_to_job,
    task_row,
)
from .lower import (  # noqa: F401
    enumerate_catalog_pairs,
    enumerate_task_pairs,
    lower,
    pad_catalog,
    pad_tiles,
    task_tiles,
)
from .comms import (  # noqa: F401
    COMMS_POLICIES,
    CommsPlan,
    comms_volume,
    default_group,
    halo_bytes_per_device,
    halo_hop_rows,
    plan_comms,
    psum_bytes_per_device,
    rewrite_tiles_local,
)
from .schedule import (  # noqa: F401
    NoHealthyDevicesError,
    Schedule,
    apply_schedule,
    device_assignment,
    schedule_tiles,
    tile_costs,
    tiles_for_devices,
)
from .feedback import (  # noqa: F401
    N_TILE_CLASSES,
    TILE_CLASS_NAMES,
    EwmaCostModel,
    GeometryCostModel,
    tile_class,
)
from .tune import (  # noqa: F401
    GEOMETRY_LATTICE,
    GeometryScore,
    TuneReport,
    autotune,
    catalog_occupancy,
)
from .faults import (  # noqa: F401
    FAULT_KINDS,
    CallPlan,
    DeviceKilledError,
    FaultEvent,
    FaultInjector,
    FaultScript,
    TransientScorerError,
)
from .execute import (  # noqa: F401
    RecoveryFailedError,
    ShardRecord,
    SupervisedReport,
    execute,
    execute_supervised,
    make_scorer,
    match_catalog,
    score_catalog,
    shard_sane,
    stage1_stats,
    verify_pairs,
)
