"""Lowering: MatchJob task rectangles → MXU-aligned tile catalogs.

``lower(job)`` is the single tiling implementation behind every
strategy (formerly six near-identical ``catalog_for_*`` builders): each
task's [a0, a0+alen) × [b0, b0+blen) window is intersected with the
aligned (block_m, block_n) grid, tiles that cannot contain a live cell
(entirely on/below the diagonal for triangular tasks, entirely above
the band) are pruned, and every surviving tile carries the task's
predicate scalars verbatim — the catalog column layout is owned by
``kernels.pair_sim`` (NCOLS = 13).

Memory: the catalog is O(#tasks + planned_pairs / (bm·bn)), never
O(P) host-side pair indices.

This module also owns the one-and-only pair-enumeration oracle
(``enumerate_catalog_pairs`` / ``enumerate_task_pairs``) — the
triangular/rect logic the reference executor and the coverage tests
share (formerly duplicated between ``er/pipeline._tile_pairs`` and
``er/executor.enumerate_catalog_pairs``).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .ir import (A_TILE, B_TILE, R0, R1, C0, C1, TRI, LB_R, LB_C, UB_R,
                 UB_C, BAND, RED, NCOLS, NO_LB, NO_UB, RED_FREE,
                 T_A0, T_ALEN, T_B0, T_BLEN, T_TRI, T_LB_R, T_LB_C,
                 T_UB_R, T_UB_C, T_BAND, T_RED, MatchJob, TileCatalog)

__all__ = [
    "task_tiles",
    "lower",
    "pad_tiles",
    "pad_catalog",
    "enumerate_task_pairs",
    "enumerate_catalog_pairs",
]


def task_tiles(a0: int, alen: int, b0: int, blen: int, tri: bool,
               reducer: int, bm: int, bn: int,
               lb: Tuple[int, int] = (NO_LB, NO_LB),
               ub: Tuple[int, int] = (NO_UB, NO_UB),
               band: int = 0) -> np.ndarray:
    """Aligned tiles intersecting one task's [a0, a0+alen) × [b0, b0+blen)
    window. Validity windows/cuts are global-row predicates, so every tile
    of a task carries the same scalars; triangular tasks drop tiles
    entirely on/below the diagonal (no row < col cell), banded tasks
    additionally drop tiles entirely above the col − row < band diagonal —
    the tile set hugs the band instead of filling the bounding rectangle."""
    if alen <= 0 or blen <= 0:
        return np.zeros((0, NCOLS), np.int32)
    ii = np.arange(a0 // bm, -(-(a0 + alen) // bm), dtype=np.int64)
    jj = np.arange(b0 // bn, -(-(b0 + blen) // bn), dtype=np.int64)
    tii, tjj = np.meshgrid(ii, jj, indexing="ij")
    tii, tjj = tii.ravel(), tjj.ravel()
    if tri:
        keep = np.maximum(tii * bm, a0) < np.minimum((tjj + 1) * bn, b0 + blen)
        tii, tjj = tii[keep], tjj[keep]
    if band > 0:
        # Some cell with col − row < band: min over the tile∩window of
        # (col − row) is clipped_col_start − (clipped_row_end − 1).
        keep = (np.maximum(tjj * bn, b0)
                < np.minimum((tii + 1) * bm, a0 + alen) + band - 1)
        tii, tjj = tii[keep], tjj[keep]
    t = np.empty((tii.size, NCOLS), np.int32)
    t[:, A_TILE] = tii
    t[:, B_TILE] = tjj
    t[:, R0] = a0
    t[:, R1] = a0 + alen
    t[:, C0] = b0
    t[:, C1] = b0 + blen
    t[:, TRI] = int(tri)
    t[:, LB_R], t[:, LB_C] = lb
    t[:, UB_R], t[:, UB_C] = ub
    t[:, BAND] = band
    t[:, RED] = reducer
    return t


def lower(job: MatchJob, block_m: int = 128,
          block_n: int = 128) -> TileCatalog:
    """Tile a MatchJob into an MXU tile catalog.

    Tiles inherit their task's reducer attribution; tasks marked
    :data:`ir.RED_FREE` (no planner attribution, e.g. the match_⊥ cross
    job) get their tiles spread round-robin over the job's r reducers —
    the cost-LPT scheduler re-places everything anyway, this only keeps
    the unscheduled catalog balanced for the legacy/round-robin paths.
    """
    parts = []
    for t in job.tasks:
        parts.append(task_tiles(
            int(t[T_A0]), int(t[T_ALEN]), int(t[T_B0]), int(t[T_BLEN]),
            bool(t[T_TRI]), int(t[T_RED]), block_m, block_n,
            lb=(int(t[T_LB_R]), int(t[T_LB_C])),
            ub=(int(t[T_UB_R]), int(t[T_UB_C])),
            band=int(t[T_BAND])))
    tiles = (np.concatenate(parts, axis=0) if parts
             else np.zeros((0, NCOLS), np.int32))
    free = tiles[:, RED] == RED_FREE
    if free.any():
        tiles[free, RED] = (np.arange(int(free.sum()), dtype=np.int32)
                            % max(job.r, 1))
    return TileCatalog(tiles=tiles, block_m=block_m, block_n=block_n,
                       n_rows_a=job.n_rows_a, n_rows_b=job.n_rows_b,
                       r=max(job.r, 1), total_pairs=job.total_pairs)


# ---------------------------------------------------------------------------
# Shape padding (the serving path's fixed-shape contract)
# ---------------------------------------------------------------------------

def pad_tiles(tiles: np.ndarray, multiple: int) -> np.ndarray:
    """Pad a tile table's second-to-last axis UP to a multiple of
    ``multiple`` rows (>= one full chunk) with all-zero entries — an
    empty validity window r0 == r1 == 0 masks everything out, so padding
    never changes survivors. Works on a flat (T, NCOLS) catalog and on
    per-device (n_dev, cap, NCOLS) shards alike; this is the one padding
    helper behind the former ``pad_catalog_tiles`` / ``_pad_tile_chunks``
    / ``pad_device_tiles`` trio."""
    t = tiles.shape[-2]
    padded = max(multiple, -(-t // multiple) * multiple)
    if padded == t:
        return tiles
    pad_shape = tiles.shape[:-2] + (padded - t, NCOLS)
    return np.concatenate(
        [tiles, np.zeros(pad_shape, np.int32)], axis=-2)


def pad_catalog(catalog: TileCatalog, multiple: int) -> TileCatalog:
    """Pad a catalog's tile table to a multiple of ``multiple`` rows, so
    a chunked scorer sees only one chunk shape — the shape-bucketing the
    serving path relies on for zero steady-state recompiles."""
    tiles = pad_tiles(catalog.tiles, multiple)
    if tiles is catalog.tiles:
        return catalog
    return TileCatalog(tiles=tiles, block_m=catalog.block_m,
                       block_n=catalog.block_n, n_rows_a=catalog.n_rows_a,
                       n_rows_b=catalog.n_rows_b, r=catalog.r,
                       total_pairs=catalog.total_pairs)


# ---------------------------------------------------------------------------
# Pair-enumeration oracle (tests + the reference executor)
# ---------------------------------------------------------------------------

def enumerate_task_pairs(a0: int, alen: int, b0: int, blen: int,
                         tri: bool) -> Tuple[np.ndarray, np.ndarray]:
    """Row-index pairs of one plain match task (no cuts/band) — the
    reference executor's O(pairs) materialization; the catalog path never
    calls this."""
    if tri:
        x, y = np.triu_indices(alen, k=1)
        return a0 + x, a0 + y
    x, y = np.meshgrid(np.arange(alen), np.arange(blen), indexing="ij")
    return a0 + x.ravel(), b0 + y.ravel()


def enumerate_catalog_pairs(catalog: TileCatalog
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize every pair a catalog covers (numpy, O(P) — tests only).

    Applies the exact kernel predicate per tile; the parity tests assert
    this equals the plan's own pair enumeration, i.e. the catalog covers
    each planned pair exactly once.
    """
    bm, bn = catalog.block_m, catalog.block_n
    gi = np.arange(bm)[:, None]
    gj = np.arange(bn)[None, :]
    out_a, out_b = [], []
    for e in catalog.tiles:
        rows = e[A_TILE].astype(np.int64) * bm + gi
        cols = e[B_TILE].astype(np.int64) * bn + gj
        keep = (rows >= e[R0]) & (rows < e[R1]) & (cols >= e[C0]) & (cols < e[C1])
        if e[TRI]:
            keep &= rows < cols
        keep &= (rows > e[LB_R]) | (cols >= e[LB_C])
        keep &= (rows < e[UB_R]) | (cols <= e[UB_C])
        if e[BAND]:
            keep &= cols - rows < e[BAND]
        ii, jj = np.nonzero(keep)
        out_a.append(rows[ii, 0])
        out_b.append(cols[0, jj])
    if not out_a:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(out_a), np.concatenate(out_b)
