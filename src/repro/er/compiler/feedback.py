"""Runtime-feedback cost calibration: the EWMA latency model that turns
``Schedule.stats()`` from a report into a control loop.

The compiler's cost model (:func:`schedule.tile_costs`) is **exact in
live pairs** but blind to **wall clock**: stage-2 survivor density makes
a banded SN tile cheaper per pair than a dense rectangle, and real
devices straggle. :class:`EwmaCostModel` closes that gap with
measurements the supervisor already produces — every accepted
:class:`~.execute.ShardRecord` is one ``(device, cost-by-tile-class,
busy seconds)`` observation folded into an exponentially weighted
moving average of *seconds per live pair*:

  * **per (device, tile class)** — the finest rate, used to predict a
    specific batch on a specific device (the work-stealing projection);
  * **per device** — the device's overall speed, used to place reducer
    loads onto heterogeneous devices (``greedy_lpt_hetero``);
  * **global** — the fleet-wide prior every unseen (device, class)
    falls back to, so one observation anywhere makes every projection
    wall-clock-scaled instead of prior-scaled.

Tile *classes* partition the catalog by predicate shape — plain
rectangles, triangular self-join tiles, SN band tiles, corner-cut
rectangles — because those are the geometries whose survivor densities
(and hence per-pair wall cost) differ systematically.
:func:`EwmaCostModel.class_rates` is the **multiplicative calibration**
``schedule_tiles`` folds onto the exact live-pair costs: calibrated
tile weight = exact pairs × class rate. The live-pair model stays the
single source of truth for coverage accounting (``reducer_load`` /
``device_load`` / ``coverage`` remain exact pair counts); calibration
only re-weights *placement*.

Virtual chaos delays count as observed time **only when an injector is
armed** — the supervisor passes ``elapsed + injected_delay`` under
injection (the simulated cluster really is that slow) and the real wall
seconds otherwise, so replayable chaos drills train the model exactly
like a slow production device would.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from .ir import BAND, LB_R, NO_LB, NO_UB, TRI, UB_R, TileCatalog

__all__ = [
    "N_TILE_CLASSES",
    "TILE_CLASS_NAMES",
    "tile_class",
    "EwmaCostModel",
    "GeometryCostModel",
]

TILE_CLASS_NAMES = ("rect", "tri", "band", "cut")
N_TILE_CLASSES = len(TILE_CLASS_NAMES)


def tile_class(catalog: TileCatalog) -> np.ndarray:
    """(T,) class id per catalog tile, by predicate shape.

    ``band`` > 0 → band (SN; band tiles are also triangular, the band
    dominates the live geometry), else ``tri`` → triangular, else an
    active lb/ub corner cut → cut, else a plain rectangle.
    """
    t = catalog.tiles
    if t.shape[0] == 0:
        return np.zeros(0, np.int64)
    cut = (t[:, LB_R] != NO_LB) | (t[:, UB_R] != NO_UB)
    out = np.zeros(t.shape[0], np.int64)          # rect
    out[cut] = 3                                  # cut
    out[t[:, TRI] != 0] = 1                       # tri
    out[t[:, BAND] > 0] = 2                       # band
    return out


class EwmaCostModel:
    """EWMA of measured seconds-per-live-pair at three resolutions.

    ``observe()`` folds one accepted shard call in; ``predict()`` /
    ``predict_tiles()`` project wall seconds for a batch on a device;
    ``device_rates()`` and ``class_rates()`` are the calibration vectors
    ``schedule_tiles`` consumes. The model is cheap host state (a few
    small arrays) meant to live as long as its fleet — the service keeps
    one across requests so steady-state serving self-tunes.
    """

    def __init__(self, n_dev: int, alpha: float = 0.35,
                 prior_rate: float = 1e-7):
        if n_dev < 1:
            raise ValueError("n_dev must be >= 1")
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.n_dev = int(n_dev)
        self.alpha = float(alpha)
        self.prior_rate = float(prior_rate)
        self.observations = 0
        self._global = float(prior_rate)
        self._dev = np.full(self.n_dev, np.nan)
        self._cls = np.full((self.n_dev, N_TILE_CLASSES), np.nan)

    # -- updates ---------------------------------------------------------

    def _fold(self, old: float, new: float) -> float:
        if math.isnan(old):
            return new
        return (1.0 - self.alpha) * old + self.alpha * new

    def observe(self, device: int, cost_by_class: np.ndarray,
                seconds: float) -> None:
        """Fold one measured shard call into the model.

        ``cost_by_class`` is the batch's exact live-pair cost per tile
        class (length :data:`N_TILE_CLASSES`); ``seconds`` the device's
        busy time for the call — real wall seconds, plus the injected
        virtual delay when a fault injector is armed (and only then).
        Seconds split across classes proportionally to each class's
        *currently predicted* share, so mixed-class calls refine every
        class they touch instead of blurring them together.
        """
        cost = np.asarray(cost_by_class, np.float64)
        if cost.shape != (N_TILE_CLASSES,):
            raise ValueError(
                f"cost_by_class must have shape ({N_TILE_CLASSES},)")
        total = float(cost.sum())
        if total <= 0 or seconds < 0:
            return
        seconds = max(float(seconds), 1e-9)
        rate = seconds / total
        self._global = self._fold(self._global, rate)
        self._dev[device] = self._fold(float(self._dev[device]), rate)
        cur = np.asarray([self.rate(device, c)
                          for c in range(N_TILE_CLASSES)])
        pred = cost * cur
        denom = float(pred.sum())
        for c in np.flatnonzero(cost > 0):
            share = pred[c] / denom if denom > 0 else cost[c] / total
            self._cls[device, c] = self._fold(
                float(self._cls[device, c]), share * seconds / cost[c])
        self.observations += 1

    def reset_device(self, device: int) -> None:
        """Forget everything learned about one device (its per-class and
        per-device rates fall back to the global prior). The circuit
        breaker calls this on re-admission: rates accumulated while the
        device straggled describe the device that got evicted, not the
        recovered one that just passed a probe — keeping them would
        under-schedule a healthy device indefinitely."""
        self._dev[device] = np.nan
        self._cls[device, :] = np.nan

    # -- queries ---------------------------------------------------------

    def rate(self, device: int, cls: Optional[int] = None) -> float:
        """Seconds per live pair: (device, class) → device → global."""
        if cls is not None and not math.isnan(self._cls[device, cls]):
            return float(self._cls[device, cls])
        if not math.isnan(self._dev[device]):
            return float(self._dev[device])
        return self._global

    @property
    def global_rate(self) -> float:
        """Fleet-wide EWMA seconds per live pair (the fallback prior)."""
        return self._global

    def device_rates(self) -> np.ndarray:
        """(n_dev,) per-device seconds per live pair, global-backed."""
        return np.asarray([self.rate(d) for d in range(self.n_dev)])

    def class_rates(self) -> np.ndarray:
        """(N_TILE_CLASSES,) fleet-level seconds per live pair per tile
        class — the device-agnostic multiplicative calibration folded
        onto exact live-pair costs. Unobserved classes fall back to the
        global rate."""
        out = np.empty(N_TILE_CLASSES)
        for c in range(N_TILE_CLASSES):
            col = self._cls[:, c]
            seen = col[~np.isnan(col)]
            out[c] = float(seen.mean()) if seen.size else self._global
        return out

    def predict(self, device: int, cost_by_class: np.ndarray) -> float:
        """Projected wall seconds for a batch on ``device``."""
        cost = np.asarray(cost_by_class, np.float64)
        return float(sum(cost[c] * self.rate(device, c)
                         for c in np.flatnonzero(cost > 0)))

    def predict_tiles(self, device: int, costs: np.ndarray,
                      classes: np.ndarray) -> float:
        """Projected wall seconds for explicit (cost, class) tile lists."""
        by_class = np.bincount(classes, weights=costs,
                               minlength=N_TILE_CLASSES)
        return self.predict(device, by_class)

    # -- persistence -----------------------------------------------------

    def to_state(self) -> dict:
        """Snapshot the learned rates as a plain JSON-able dict, so a
        restarted service warm-starts its scheduler instead of relearning
        the fleet from the prior (``ERService.export_feedback_state``)."""
        return {
            "version": 1,
            "n_dev": self.n_dev,
            "alpha": self.alpha,
            "prior_rate": self.prior_rate,
            "observations": self.observations,
            "global": self._global,
            "dev": [None if math.isnan(v) else float(v)
                    for v in self._dev],
            "cls": [[None if math.isnan(v) else float(v) for v in row]
                    for row in self._cls],
        }

    @classmethod
    def from_state(cls, state: dict) -> "EwmaCostModel":
        """Rebuild a model from :meth:`to_state` output. Exact
        round-trip: ``from_state(m.to_state())`` predicts identically to
        ``m`` and keeps folding observations with the same alpha."""
        if state.get("version") != 1:
            raise ValueError(f"unknown EwmaCostModel state version: "
                             f"{state.get('version')!r}")
        m = cls(int(state["n_dev"]), alpha=float(state["alpha"]),
                prior_rate=float(state["prior_rate"]))
        m.observations = int(state["observations"])
        m._global = float(state["global"])
        m._dev = np.asarray(
            [np.nan if v is None else v for v in state["dev"]], np.float64)
        m._cls = np.asarray(
            [[np.nan if v is None else v for v in row]
             for row in state["cls"]], np.float64)
        if m._dev.shape != (m.n_dev,) or m._cls.shape != (m.n_dev,
                                                          N_TILE_CLASSES):
            raise ValueError("EwmaCostModel state shape mismatch")
        return m


class GeometryCostModel:
    """Geometry-keyed EWMA of measured seconds-per-live-pair, the online
    half of the tile-geometry autotuner (er/compiler/tune.py).

    A catalog's *live pair count* is geometry-invariant (it is the
    plan's pair total — only the dead padding around those pairs changes
    with (block_m, block_n)), so seconds-per-live-pair measured under
    different geometries rank the geometries directly: the one that
    wastes the least MXU time per useful pair wins. ``observe()`` folds
    one measured sweep leg or serving batch; ``rate()`` falls back to
    NaN for unmeasured geometries (the static occupancy model keeps
    ranking those); ``best()`` returns the measured argmin.
    """

    def __init__(self, alpha: float = 0.35):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = float(alpha)
        self.observations = 0
        self._rate: dict = {}          # (block_m, block_n) -> EWMA s/pair

    def observe(self, geometry, live_pairs: float, seconds: float) -> None:
        """Fold one measured stage-1 call at ``geometry`` over a catalog
        with ``live_pairs`` exact live pairs taking ``seconds`` wall."""
        key = (int(geometry[0]), int(geometry[1]))
        if live_pairs <= 0 or seconds < 0:
            return
        new = max(float(seconds), 1e-9) / float(live_pairs)
        old = self._rate.get(key)
        self._rate[key] = new if old is None else (
            (1.0 - self.alpha) * old + self.alpha * new)
        self.observations += 1

    def rate(self, geometry) -> float:
        """EWMA seconds per live pair at ``geometry``; NaN if unmeasured."""
        return self._rate.get((int(geometry[0]), int(geometry[1])),
                              float("nan"))

    def best(self, candidates=None):
        """Measured-best geometry among ``candidates`` (default: every
        measured geometry); None when nothing relevant is measured."""
        pool = (self._rate if candidates is None
                else {k: self._rate[k] for k in
                      ((int(g[0]), int(g[1])) for g in candidates)
                      if k in self._rate})
        if not pool:
            return None
        return min(pool, key=pool.get)

    def to_state(self) -> dict:
        """JSON-able snapshot (same restart story as
        :meth:`EwmaCostModel.to_state`)."""
        return {
            "version": 1,
            "alpha": self.alpha,
            "observations": self.observations,
            "rates": [[k[0], k[1], v] for k, v in sorted(self._rate.items())],
        }

    @classmethod
    def from_state(cls, state: dict) -> "GeometryCostModel":
        if state.get("version") != 1:
            raise ValueError(f"unknown GeometryCostModel state version: "
                             f"{state.get('version')!r}")
        m = cls(alpha=float(state["alpha"]))
        m.observations = int(state["observations"])
        m._rate = {(int(bm), int(bn)): float(v)
                   for bm, bn, v in state["rates"]}
        return m
