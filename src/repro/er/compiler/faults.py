"""Deterministic fault injection for the supervised execution runtime.

A *fault script* is a replayable sequence of :class:`FaultEvent`\\ s, each
armed at a global **step** — the number of device-shard calls the
injector has served so far. The supervisor
(:func:`compiler.execute.execute_supervised`) consults the injector
before every shard it scores, so the same script against the same
catalog replays the exact same chaos scenario, call for call. The
failure taxonomy (DESIGN.md §Fault tolerance):

  * ``kill``      — the device stops answering from this step on: every
                    shard call raises :class:`DeviceKilledError` until a
                    later ``revive`` event re-arms it. Models a lost
                    node / preempted VM.
  * ``revive``    — the device answers again (the circuit breaker's
                    probe path re-admits it at the service level).
  * ``straggle``  — ONE shard call on the device reports ``delay``
                    extra virtual seconds; the supervisor treats a call
                    whose (wall + injected) latency exceeds the shard
                    deadline as timed out and DISCARDS its output.
                    Models a slow disk / noisy neighbor. With
                    ``sticky=True`` the delay applies to EVERY call on
                    the device from its step on (a persistently slow
                    node — the work-stealing drill's straggler) until a
                    ``revive`` clears it.
  * ``transient`` — ONE shard call raises
                    :class:`TransientScorerError`; the device stays
                    healthy (retry-able). Models an RPC blip.
  * ``corrupt``   — ONE shard call returns garbage survivor rows
                    (seeded out-of-bounds indices), which the
                    supervisor's sanity check must catch and discard.
                    Models a bad host buffer / bit flip.

Delays are *virtual*: the injector reports them as numbers instead of
sleeping, so chaos suites run at full speed and stay deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultScript",
    "FaultInjector",
    "CallPlan",
    "DeviceKilledError",
    "TransientScorerError",
]

FAULT_KINDS = ("kill", "revive", "straggle", "transient", "corrupt")


class DeviceKilledError(RuntimeError):
    """The injected cluster lost this device: the shard call never
    returns. The supervisor marks the device unhealthy and reschedules
    its tiles."""


class TransientScorerError(RuntimeError):
    """A one-shot scorer failure (RPC blip): the shard is lost but the
    device stays healthy for the next round."""


@dataclass(frozen=True)
class FaultEvent:
    kind: str               # one of FAULT_KINDS
    device: int
    step: int               # arms once the injector has served >= step calls
    delay: float = 0.0      # straggle: virtual seconds added to the call
    sticky: bool = False    # straggle: delay EVERY call until revived

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass(frozen=True)
class FaultScript:
    """An ordered, replayable chaos scenario over ``n_dev`` devices."""
    events: Tuple[FaultEvent, ...]
    n_dev: int

    @staticmethod
    def random(seed: int, n_dev: int, n_events: int, *,
               max_step: int = 32, straggle_delay: float = 1e9,
               fatal_frac: float = 1.0,
               allow_revive: bool = False) -> "FaultScript":
        """A seeded random script that NEVER makes every device fatal at
        once: kills and deadline-busting straggles consume a fatal
        budget of ``n_dev - 1`` devices; once spent, only non-fatal
        events (transient, corrupt, sub-deadline straggles) are drawn.
        ``straggle_delay`` is the virtual delay of a *fatal* straggle —
        pass something far above the supervisor's shard deadline.
        ``fatal_frac`` scales how much of the budget may be used."""
        rng = np.random.default_rng(seed)
        fatal: Set[int] = set()
        budget = max(int((n_dev - 1) * fatal_frac), 0)
        events: List[FaultEvent] = []
        for _ in range(n_events):
            step = int(rng.integers(0, max_step))
            dev = int(rng.integers(0, n_dev))
            kind = str(rng.choice(FAULT_KINDS))
            if kind == "revive":
                if not allow_revive or not fatal:
                    kind = "transient"
                else:
                    dev = int(rng.choice(sorted(fatal)))
                    fatal.discard(dev)
            if kind in ("kill", "straggle") and dev not in fatal:
                is_fatal = kind == "kill" or bool(rng.integers(0, 2))
                if is_fatal:
                    if len(fatal) >= budget:
                        kind = "transient" if kind == "kill" else "straggle"
                        is_fatal = False
                    else:
                        fatal.add(dev)
                if kind == "straggle":
                    delay = (straggle_delay if is_fatal
                             else 0.0)  # sub-deadline: harmless blip
                    events.append(FaultEvent("straggle", dev, step, delay))
                    continue
            if kind == "straggle":
                events.append(FaultEvent("straggle", dev, step, 0.0))
                continue
            events.append(FaultEvent(kind, dev, step))
        return FaultScript(events=tuple(events), n_dev=n_dev)


@dataclass
class CallPlan:
    """What the injector decided for one shard call."""
    delay: float = 0.0       # virtual seconds to add to the call's latency
    corrupt: bool = False    # garble this call's survivor output


class FaultInjector:
    """Replays a :class:`FaultScript` against a stream of shard calls.

    The supervisor calls :meth:`shard_call` before scoring each device
    shard; the injector advances its global step counter, arms every
    event whose step has been reached, and either raises (kill /
    transient) or returns a :class:`CallPlan` (possible straggle delay,
    possible output corruption). :meth:`corrupt_output` garbles a result
    with seeded out-of-bounds rows — always detectable by the
    supervisor's bounds check, by construction.
    """

    def __init__(self, script: FaultScript, seed: int = 0):
        self.script = script
        self.step = 0
        self._pending = sorted(script.events, key=lambda e: e.step)
        self._dead: Set[int] = set()
        self._straggle: Dict[int, List[float]] = {}
        self._slow: Dict[int, float] = {}
        self._transient: Dict[int, int] = {}
        self._corrupt: Dict[int, int] = {}
        self._rng = np.random.default_rng(seed)

    # -- script replay -------------------------------------------------

    def _arm(self):
        while self._pending and self._pending[0].step <= self.step:
            e = self._pending.pop(0)
            if e.kind == "kill":
                self._dead.add(e.device)
            elif e.kind == "revive":
                self._dead.discard(e.device)
                self._slow.pop(e.device, None)
            elif e.kind == "straggle":
                if e.sticky:
                    self._slow[e.device] = e.delay
                else:
                    self._straggle.setdefault(e.device, []).append(e.delay)
            elif e.kind == "transient":
                self._transient[e.device] = \
                    self._transient.get(e.device, 0) + 1
            elif e.kind == "corrupt":
                self._corrupt[e.device] = self._corrupt.get(e.device, 0) + 1

    def shard_call(self, device: int) -> CallPlan:
        """Account one shard call on ``device``; raise or return a plan."""
        self.step += 1
        self._arm()
        if device in self._dead:
            raise DeviceKilledError(f"device {device} is down")
        if self._transient.get(device, 0) > 0:
            self._transient[device] -= 1
            raise TransientScorerError(f"device {device}: transient fault")
        plan = CallPlan()
        plan.delay = self._slow.get(device, 0.0)
        q = self._straggle.get(device)
        if q:
            plan.delay += q.pop(0)
        if self._corrupt.get(device, 0) > 0:
            self._corrupt[device] -= 1
            plan.corrupt = True
        return plan

    # -- corruption ----------------------------------------------------

    def corrupt_output(self, rows_a: np.ndarray, rows_b: np.ndarray,
                       n_a: int, n_b: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Garble a shard's survivor rows: scramble a seeded subset and
        append at least one out-of-bounds pair, so the supervisor's
        cheap bounds check is guaranteed to reject the shard."""
        ra = np.array(rows_a, np.int64, copy=True)
        rb = np.array(rows_b, np.int64, copy=True)
        if ra.size:
            k = max(1, ra.size // 4)
            idx = self._rng.choice(ra.size, size=k, replace=False)
            ra[idx] = self._rng.integers(-n_a - 8, 2 * n_a + 8, size=k)
        extra = int(self._rng.integers(1, 4))
        ra = np.concatenate([ra, np.full(extra, n_a + 7, np.int64)])
        rb = np.concatenate([rb, np.full(extra, n_b + 7, np.int64)])
        return ra, rb

    # -- introspection -------------------------------------------------

    @property
    def dead_devices(self) -> Set[int]:
        """Devices currently down (ground truth, for drills/benchmarks)."""
        return set(self._dead)

    @property
    def slow_devices(self) -> Dict[int, float]:
        """Devices with a sticky straggle armed: device → per-call delay."""
        return dict(self._slow)
