"""Tile-geometry autotuning: pick per-catalog (block_m, block_n) from an
MXU-aligned lattice by exact occupancy, refined online by wall clock.

The lowering quantum IS the load-balance floor (the paper balances at
sub-block granularity, §IV), and it is also the MXU-occupancy knob: a
skewed BDM's long tail of small blocks lowers into tiles that are mostly
dead cells at 128×128 — the kernel multiplies padding. The autotuner
closes DESIGN §Perf's "tighter tile sizes per block-size histogram"
hillclimb with two signals:

  * **Exact occupancy** (static): lower the job at each candidate
    geometry and take ``waste = T·bm·bn − Σ tile_costs``. The live-pair
    sum is *geometry-invariant* (it is the plan's pair total — only the
    dead padding moves), and ``tile_costs`` is exact, so the waste model
    equals enumerated dead cells by construction (property-tested in
    tests/test_tile_geometry.py). The static score adds per-tile strip
    DMA traffic and fixed grid-step overhead on top of the cell count:
    ``T·(bm·bn + beta·(bm+bn) + tile_overhead)`` — a roofline in
    cell-equivalents that keeps tiny tiles from winning on occupancy
    alone while drowning in per-tile overhead.
  * **Measured seconds-per-live-pair** (online): a geometry-keyed EWMA
    (:class:`~.feedback.GeometryCostModel`). Because live pairs are
    geometry-invariant, measured rates rank geometries directly;
    candidates the model has measured use their EWMA rate, unmeasured
    ones are bridged through a fitted seconds-per-model-unit scale so
    one measurement anywhere wall-clock-anchors the whole lattice.

Candidates whose double-buffered working set exceeds the VMEM budget
(:func:`~...kernels.pair_sim.catalog_vmem_bytes`) are dropped before
scoring. The lattice is finite and every geometry is a static kernel
arg, so a resident service compiles at most |lattice| variants during
its warmup sweep and then pins the winner — zero steady-state
recompiles (asserted by benchmarks/tune_bench.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from ...kernels.pair_sim import (GEOMETRY_LATTICE, VMEM_BUDGET_BYTES,
                                 catalog_vmem_bytes)
from .feedback import GeometryCostModel
from .ir import MatchJob, TileCatalog
from .lower import lower
from .schedule import tile_costs

__all__ = [
    "GEOMETRY_LATTICE",
    "GeometryScore",
    "TuneReport",
    "catalog_occupancy",
    "autotune",
]

# Static-model coefficients, in dead-cell equivalents: ``beta`` weighs
# per-tile strip DMA traffic (bm+bn rows moved per tile; double
# buffering overlaps it with compute but HBM bandwidth still bounds),
# ``tile_overhead`` the fixed per-grid-step cost (descriptor decode,
# epilogue, DMA issue). Calibrated once against the Fig. 9 sweep in
# benchmarks/tune_bench.py; the online EWMA overrides them as soon as
# real measurements exist.
DEFAULT_BETA = 32.0
DEFAULT_TILE_OVERHEAD = 4096.0


@dataclass(frozen=True)
class GeometryScore:
    """One lattice candidate's exact occupancy + model/measured cost."""
    block_m: int
    block_n: int
    tiles: int              # catalog entries T at this geometry
    cells: int              # T · bm · bn scored MXU cells
    live_pairs: int         # Σ tile_costs — geometry-invariant
    waste: int              # cells − live_pairs (exact dead cells)
    occupancy: float        # live_pairs / cells (0 for empty catalogs)
    model_cost: float       # static roofline, cell-equivalents
    measured_rate: float    # EWMA seconds/live-pair; NaN if unmeasured
    predicted_seconds: float  # NaN when nothing in the lattice is measured

    @property
    def geometry(self) -> Tuple[int, int]:
        return (self.block_m, self.block_n)


@dataclass(frozen=True)
class TuneReport:
    """Autotune outcome: the chosen geometry + the full candidate table
    (sorted best-first) for benchmarks and logs."""
    block_m: int
    block_n: int
    measured: bool          # True when the choice used EWMA wall clock
    scores: Tuple[GeometryScore, ...]

    @property
    def geometry(self) -> Tuple[int, int]:
        return (self.block_m, self.block_n)

    @property
    def best(self) -> GeometryScore:
        return self.scores[0]


def catalog_occupancy(catalog: TileCatalog) -> Tuple[int, int, int]:
    """(cells, live_pairs, waste) of a lowered catalog — exact, from the
    closed-form cost model. ``waste`` equals the number of tile cells
    whose predicate mask is dead (enumerable but never enumerated)."""
    t = catalog.tiles.shape[0]
    cells = t * catalog.block_m * catalog.block_n
    live = int(tile_costs(catalog).sum())
    return cells, live, cells - live


def _score_one(job: MatchJob, bm: int, bn: int, beta: float,
               tile_overhead: float,
               feedback: Optional[GeometryCostModel]) -> GeometryScore:
    catalog = lower(job, bm, bn)
    cells, live, waste = catalog_occupancy(catalog)
    t = catalog.tiles.shape[0]
    model = t * (bm * bn + beta * (bm + bn) + tile_overhead)
    rate = feedback.rate((bm, bn)) if feedback is not None else float("nan")
    return GeometryScore(
        block_m=bm, block_n=bn, tiles=t, cells=cells, live_pairs=live,
        waste=waste, occupancy=(live / cells if cells else 0.0),
        model_cost=model, measured_rate=rate,
        predicted_seconds=float("nan"))


def autotune(job: MatchJob, *,
             lattice: Sequence[Tuple[int, int]] = GEOMETRY_LATTICE,
             d: int = 0, capacity: int = 0, beta: float = DEFAULT_BETA,
             tile_overhead: float = DEFAULT_TILE_OVERHEAD,
             feedback: Optional[GeometryCostModel] = None) -> TuneReport:
    """Choose (block_m, block_n) for ``job`` from ``lattice``.

    ``d`` (feature dim) and ``capacity`` (compaction slots), when given,
    drop candidates whose double-buffered VMEM working set exceeds the
    budget. With a :class:`GeometryCostModel` holding at least one
    measured lattice candidate, ranking is by predicted wall seconds —
    measured candidates at ``rate · live_pairs``, unmeasured ones
    bridged via the fitted seconds-per-model-unit of the measured set.
    Otherwise ranking is by the static model alone.
    """
    cands = []
    for bm, bn in lattice:
        if d and catalog_vmem_bytes(bm, bn, d, capacity) > VMEM_BUDGET_BYTES:
            continue
        cands.append((int(bm), int(bn)))
    if not cands:
        raise ValueError(
            f"no lattice candidate fits VMEM at d={d}, capacity={capacity}")

    scores = [_score_one(job, bm, bn, beta, tile_overhead, feedback)
              for bm, bn in cands]

    # Wall-clock anchor: fit seconds-per-model-unit over measured
    # candidates, project it onto unmeasured ones. live_pairs is the
    # same for every candidate, so measured ranks need no bridging
    # among themselves — the fit only grafts the two populations onto
    # one axis.
    measured = [s for s in scores if not math.isnan(s.measured_rate)]
    use_measured = bool(measured)
    if use_measured:
        kappa = float(np.mean([s.measured_rate * max(s.live_pairs, 1)
                               / s.model_cost for s in measured]))
        scores = [
            GeometryScore(
                **{**s.__dict__,
                   "predicted_seconds":
                       (s.measured_rate * max(s.live_pairs, 1)
                        if not math.isnan(s.measured_rate)
                        else kappa * s.model_cost)})
            for s in scores]
        scores.sort(key=lambda s: s.predicted_seconds)
    else:
        scores.sort(key=lambda s: s.model_cost)

    best = scores[0]
    return TuneReport(block_m=best.block_m, block_n=best.block_n,
                      measured=use_measured, scores=tuple(scores))
