"""Cost-model tile scheduling: tiles → reducers → devices via greedy LPT.

The paper's point (§IV) is that load balance comes from scheduling match
work by its TRUE cost, not by block or tile count. After lowering, the
unit of work is a catalog tile, and its true cost is the number of cells
that survive the tile's predicates — corner-cut tiles at a PairRange
boundary may hold 3 live pairs while an interior tile holds bm·bn. The
cost model here is **exact**: every predicate the kernel evaluates
(validity window, triangular mask, lb/ub corner cuts, the SN band) is a
per-row column *interval* constraint, so the live count is a sum of bm
interval lengths — O(T·bm), closed form, no enumeration.

``schedule_tiles`` feeds those costs to ``core.assignment.greedy_lpt``
twice — tiles → r reducers, then reducer loads → healthy devices —
replacing the per-strategy hardcoded reducer column and the
reducer → device round-robin. Round-robin remains available as the
baseline policy (and the elasticity unit ``device_assignment`` keeps its
pure-function-of-(r, healthy) restart story).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ...core.assignment import greedy_lpt, greedy_lpt_hetero, makespan_stats
from .ir import (A_TILE, B_TILE, R0, R1, C0, C1, TRI, LB_R, LB_C, UB_R,
                 UB_C, BAND, RED, NCOLS, TileCatalog)

__all__ = [
    "NoHealthyDevicesError",
    "Schedule",
    "tile_costs",
    "schedule_tiles",
    "apply_schedule",
    "tiles_for_devices",
    "device_assignment",
]


class NoHealthyDevicesError(ValueError):
    """Every device in the healthy mask is down — nothing can run.

    A ValueError subclass so callers that matched the former bare
    ``ValueError("no healthy devices")`` keep working; the service layer
    turns it into a clean retry-after error instead of a traceback."""

_COST_SLAB = 65_536     # tiles per cost-model slab: caps peak memory at
                        # O(slab · block_m) int64 regardless of plan size

POLICIES = ("cost_lpt", "round_robin")


def tile_costs(catalog: TileCatalog) -> np.ndarray:
    """Exact live-pair count per tile under ALL catalog predicates.

    For a fixed row, every predicate constrains the column to one
    interval: the validity window gives [c0, c1), the tile bounds give
    [b_tile·bn, (b_tile+1)·bn), tri demands col ≥ row+1, the band
    demands col < row+band, the lb cut applies col ≥ lb_c only on rows
    ≤ lb_r, the ub cut applies col ≤ ub_c only on rows ≥ ub_r. The live
    count is Σ_rows max(0, hi − lo) — exact, vectorized, O(T·bm)."""
    tiles = catalog.tiles
    if tiles.shape[0] == 0:
        return np.zeros(0, np.int64)
    bm, bn = catalog.block_m, catalog.block_n
    ar = np.arange(bm, dtype=np.int64)[None, :]
    out = np.empty(tiles.shape[0], np.int64)
    for s in range(0, tiles.shape[0], _COST_SLAB):
        t = tiles[s:s + _COST_SLAB].astype(np.int64)
        rows = t[:, A_TILE, None] * bm + ar
        lo = np.maximum(t[:, C0, None], t[:, B_TILE, None] * bn)
        hi = np.minimum(t[:, C1, None], (t[:, B_TILE, None] + 1) * bn)
        lo = np.where(t[:, TRI, None] != 0, np.maximum(lo, rows + 1), lo)
        hi = np.where(t[:, BAND, None] > 0,
                      np.minimum(hi, rows + t[:, BAND, None]), hi)
        lo = np.where(rows <= t[:, LB_R, None],
                      np.maximum(lo, t[:, LB_C, None]), lo)
        hi = np.where(rows >= t[:, UB_R, None],
                      np.minimum(hi, t[:, UB_C, None] + 1), hi)
        valid = (rows >= t[:, R0, None]) & (rows < t[:, R1, None])
        out[s:s + _COST_SLAB] = (np.maximum(hi - lo, 0) * valid).sum(axis=1)
    return out


@dataclass(frozen=True)
class Schedule:
    """A placement of catalog tiles onto reducers onto devices."""
    policy: str
    tile_cost: np.ndarray       # (T,) exact live pairs per tile
    tile_reducer: np.ndarray    # (T,) tile → reduce task
    reducer_device: np.ndarray  # (r,) reduce task → device
    reducer_load: np.ndarray    # (r,) live pairs per reduce task
    device_load: np.ndarray     # (n_dev,) live pairs per device
    healthy: np.ndarray         # (n_dev,) bool
    # Runtime-feedback calibration (None without an EwmaCostModel):
    device_rate: Optional[np.ndarray] = None  # (n_dev,) s per live pair
    predicted_s: Optional[np.ndarray] = None  # (n_dev,) projected seconds
    # Interconnect plan (None = flat all-gather): a comms.CommsPlan.
    # When set, ``execute`` uses ITS locality tile placement instead of
    # the cost-LPT one above (the hop bound depends on it) and surfaces
    # the plan's byte accounting through ``stats()``.
    comms: Optional[object] = None

    @property
    def n_dev(self) -> int:
        return int(self.device_load.shape[0])

    @property
    def calibrated(self) -> bool:
        return self.predicted_s is not None

    def stats(self) -> Dict:
        """The paper's balance metrics at both scheduling levels, plus —
        when the schedule was EWMA-calibrated — the wall-clock makespan
        the feedback model projects (compare against the supervisor's
        ``SupervisedReport.measured_makespan_s``)."""
        out = {
            "policy": self.policy,
            "tiles": int(self.tile_cost.shape[0]),
            "total_cost": int(self.tile_cost.sum()),
            "reducer": makespan_stats(self.reducer_load),
            "device": makespan_stats(self.device_load[self.healthy]),
            "calibrated": self.calibrated,
        }
        if self.predicted_s is not None:
            alive = self.predicted_s[self.healthy]
            out["predicted_makespan_s"] = (float(alive.max())
                                           if alive.size else 0.0)
        if self.comms is not None:
            out["interconnect"] = self.comms.summary()
        return out


def device_assignment(r: int, n_dev: int,
                      healthy: Optional[np.ndarray] = None) -> np.ndarray:
    """reducer k → device, round-robin over the *healthy* devices, so a
    failed/straggling device's work shards re-spread evenly — the plan is
    a pure function of (r, healthy mask), recomputable anywhere (the BDM
    restart argument, DESIGN.md §3). The baseline the cost-LPT scheduler
    is benchmarked against, and the fallback when no schedule is given."""
    if healthy is None:
        healthy = np.ones(n_dev, bool)
    alive = np.flatnonzero(healthy)
    if alive.size == 0:
        raise NoHealthyDevicesError("no healthy devices")
    return alive[np.arange(r) % alive.size]


def schedule_tiles(catalog: TileCatalog, *, n_dev: int = 1,
                   healthy: Optional[np.ndarray] = None,
                   policy: str = "cost_lpt",
                   feedback=None, comms_plan=None) -> Schedule:
    """Assign tiles → reducers → devices.

    ``policy="cost_lpt"``: greedy LPT over exact tile costs fills the r
    reduce tasks, then greedy LPT over reducer loads fills the healthy
    devices — both via ``core.assignment.greedy_lpt`` (the paper's
    BlockSplit heuristic, applied at tile granularity).
    ``policy="round_robin"``: keep the plan's reducer attribution and
    route reducers → devices round-robin (the pre-scheduler behavior,
    kept as the benchmark baseline).

    ``feedback=`` an :class:`~.feedback.EwmaCostModel` with at least one
    observation turns the cost-LPT placement into a *calibrated* one:
    tile weights become exact live pairs × the measured per-tile-class
    rate (the multiplicative calibration — the exact pair counts still
    back every coverage metric), and reducer loads land on devices via
    finish-time LPT over the measured per-device rates
    (:func:`core.assignment.greedy_lpt_hetero`), so a slow device gets
    proportionally less work. The projection lands on
    ``Schedule.predicted_s`` / ``stats()["predicted_makespan_s"]``.

    ``comms_plan=`` attaches a :class:`~.comms.CommsPlan` — ``execute``
    then uses the plan's locality tile placement (its hop bound depends
    on tiles landing on their minimum needed strip, which overrides the
    cost-LPT device routing above; reducer attribution and the balance
    metrics are unchanged) and ``stats()`` reports the plan's per-flow
    interconnect bytes under ``"interconnect"``.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown schedule policy {policy!r}")
    if healthy is None:
        healthy = np.ones(n_dev, bool)
    healthy = np.asarray(healthy, bool)
    alive = np.flatnonzero(healthy)
    if alive.size == 0:
        raise NoHealthyDevicesError("no healthy devices")
    r = catalog.r
    costs = tile_costs(catalog)
    device_rate = predicted_s = None
    calibrate = (feedback is not None and policy == "cost_lpt"
                 and feedback.observations > 0)
    if calibrate:
        from .feedback import tile_class
        sec = costs * feedback.class_rates()[tile_class(catalog)]
        # greedy_lpt weighs int64: scale predicted seconds to ~ns so the
        # exact-cost tie-breaking order is preserved at any magnitude.
        scale = 2.0 ** 40 / max(float(sec.max()), 1e-30) if sec.size else 1.0
        tile_reducer, _ = greedy_lpt(
            np.round(sec * scale).astype(np.int64), r)
        reducer_sec = np.bincount(tile_reducer, weights=sec, minlength=r)
        device_rate = feedback.device_rates()
        rel = device_rate / max(feedback.global_rate, 1e-300)
        on_alive, _, finish = greedy_lpt_hetero(reducer_sec, rel[alive])
        reducer_device = alive[on_alive]
        predicted_s = np.zeros(n_dev)
        predicted_s[alive] = finish
        reducer_load = np.bincount(
            tile_reducer, weights=costs, minlength=r).astype(np.int64)
    elif policy == "cost_lpt":
        tile_reducer, reducer_load = greedy_lpt(costs, r)
        on_alive, _ = greedy_lpt(reducer_load, alive.size)
        reducer_device = alive[on_alive]
    else:
        tile_reducer = catalog.tiles[:, RED].astype(np.int64)
        reducer_load = np.bincount(
            tile_reducer, weights=costs, minlength=r).astype(np.int64)
        reducer_device = device_assignment(r, n_dev, healthy)
    device_load = np.bincount(
        reducer_device, weights=reducer_load, minlength=n_dev).astype(np.int64)
    return Schedule(policy=policy, tile_cost=costs,
                    tile_reducer=tile_reducer, reducer_device=reducer_device,
                    reducer_load=reducer_load, device_load=device_load,
                    healthy=healthy, device_rate=device_rate,
                    predicted_s=predicted_s, comms=comms_plan)


def apply_schedule(catalog: TileCatalog, schedule: Schedule) -> TileCatalog:
    """Rewrite the catalog's reducer column to the scheduled placement."""
    tiles = catalog.tiles.copy()
    tiles[:, RED] = schedule.tile_reducer.astype(np.int32)
    return TileCatalog(tiles=tiles, block_m=catalog.block_m,
                       block_n=catalog.block_n, n_rows_a=catalog.n_rows_a,
                       n_rows_b=catalog.n_rows_b, r=catalog.r,
                       total_pairs=catalog.total_pairs)


def tiles_for_devices(catalog: TileCatalog, n_dev: int,
                      healthy: Optional[np.ndarray] = None,
                      schedule: Optional[Schedule] = None) -> np.ndarray:
    """Partition a tile catalog over devices, per-device tile lists padded
    to a common cap with all-zero entries (empty validity window → no
    survivors). With a :class:`Schedule`, tiles follow its cost-LPT
    tile → reducer → device placement (and carry the scheduled reducer
    in their RED column); without one, reducers route round-robin via
    :func:`device_assignment`. Returns (n_dev, cap, NCOLS) int32 —
    O(#tiles) metadata, the only plan state crossing the host/device
    boundary."""
    if schedule is not None:
        if schedule.n_dev != n_dev:
            raise ValueError(
                f"schedule was built for {schedule.n_dev} devices, not {n_dev}")
        if healthy is not None and not np.array_equal(
                np.asarray(healthy, bool), schedule.healthy):
            raise ValueError(
                "healthy mask differs from the schedule's — rebuild the "
                "schedule with schedule_tiles(..., healthy=...)")
        tiles = apply_schedule(catalog, schedule).tiles
        dev = (schedule.reducer_device[schedule.tile_reducer]
               if tiles.shape[0] else np.zeros(0, np.int64))
    else:
        tiles = catalog.tiles
        dev_of = device_assignment(catalog.r, n_dev, healthy)
        dev = (dev_of[tiles[:, RED]] if catalog.num_tiles
               else np.zeros(0, np.int64))
    counts = np.bincount(dev, minlength=n_dev)
    cap = max(1, int(counts.max()) if counts.size else 1)
    out = np.zeros((n_dev, cap, NCOLS), np.int32)
    for d in range(n_dev):
        mine = tiles[dev == d]
        out[d, :mine.shape[0]] = mine
    return out
