"""Skew-aware sequence packing — the paper's LPT assignment reused at the
data-pipeline layer (DESIGN.md §2): documents are "match tasks" weighted
by token count, microbatch rows are "reduce tasks", and greedy LPT packs
variable-length documents into equal-token rows. The same skew problem —
a few huge documents starving the batch — and the same fix.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from ..core.assignment import greedy_lpt, makespan_stats

__all__ = ["lpt_pack", "pack_documents"]


def lpt_pack(doc_lengths: Sequence[int], n_rows: int) -> Tuple[np.ndarray, dict]:
    """Assign docs to rows by greedy LPT over token counts.

    Returns (row_of_doc (n_docs,), balance stats)."""
    w = np.asarray(doc_lengths, np.int64)
    assignment, loads = greedy_lpt(w, n_rows)
    return assignment, makespan_stats(loads)


def pack_documents(docs: List[np.ndarray], n_rows: int, row_len: int,
                   pad_id: int = 0, eos_id: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Pack token arrays into (n_rows, row_len) with LPT balancing.

    Docs overflowing their row are truncated (counted in stats); rows are
    padded with ``pad_id``. Returns (tokens, loss_mask) — mask excludes
    padding and the EOS separators' successors crossing document bounds.
    """
    lengths = [len(d) + 1 for d in docs]  # +1 for EOS separator
    assignment, _ = lpt_pack(lengths, n_rows)
    tokens = np.full((n_rows, row_len), pad_id, np.int32)
    mask = np.zeros((n_rows, row_len), bool)
    cursor = np.zeros(n_rows, np.int64)
    for doc, row in zip(docs, assignment):
        r = int(row)
        take = min(len(doc), row_len - int(cursor[r]) - 1)
        if take <= 0:
            continue
        lo = int(cursor[r])
        tokens[r, lo:lo + take] = doc[:take]
        tokens[r, lo + take] = eos_id
        mask[r, lo:lo + take + 1] = True
        cursor[r] += take + 1
    return tokens, mask
