from .packing import lpt_pack, pack_documents  # noqa: F401
from .pipeline import synthetic_lm_batches  # noqa: F401
