"""Synthetic LM data pipeline for the example drivers and smoke tests.

Generates a deterministic token stream with enough structure that the
cross-entropy visibly falls within a few hundred steps (a first-order
Markov chain over the vocab), packed into (batch, seq) with next-token
labels. Document lengths are Zipf-skewed so the LPT packer has real skew
to balance — the data-pipeline face of the paper's problem.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from .packing import pack_documents

__all__ = ["synthetic_lm_batches"]


def synthetic_lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
                         markov_temp: float = 0.3) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    # sparse-ish Markov transition table: each token has 8 likely successors
    succ = rng.integers(0, vocab, (vocab, 8))
    while True:
        docs = []
        total = 0
        while total < batch * seq:
            ln = min(int(rng.zipf(1.7) * 32), 4 * seq)   # skewed doc lengths
            t = np.empty(ln, np.int32)
            t[0] = rng.integers(0, vocab)
            for i in range(1, ln):
                if rng.random() < 1 - markov_temp:
                    t[i] = succ[t[i - 1], rng.integers(0, 8)]
                else:
                    t[i] = rng.integers(0, vocab)
            docs.append(t)
            total += ln + 1
        tokens, mask = pack_documents(docs, batch, seq + 1)
        labels = np.where(mask[:, 1:], tokens[:, 1:], -100).astype(np.int32)
        yield {"tokens": tokens[:, :-1].astype(np.int32), "labels": labels}
