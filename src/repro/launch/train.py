"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
        --steps 300 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

On this CPU container use ``--reduced`` (same family, small dims). On a
real pod the full config + production mesh engage automatically when
enough devices are present. Features: cosine LR, grad clipping, async
step-sharded checkpointing with auto-resume, step-time/tokens-per-sec
logging, deterministic synthetic data (swap in a real corpus via
--data).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--reduced", action="store_true",
                   help="shrunken same-family config (CPU-friendly)")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro.configs import get_config, reduced
    from repro.data import synthetic_lm_batches
    from repro.models import get_model
    from repro.train import adamw_init, make_train_step
    from repro.train.checkpoint import async_save, latest_step, restore
    from repro.train.optimizer import AdamWConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mod = get_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, total_steps=args.steps))

    params = mod.init(cfg, jax.random.key(args.seed))
    opt_state = adamw_init(params, opt_cfg)
    start = 0
    saver = None
    if args.ckpt_dir:
        saver = async_save(args.ckpt_dir)
        if latest_step(args.ckpt_dir) is not None:
            state, start = restore(args.ckpt_dir)
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params:,} "
          f"devices={len(jax.devices())}")

    data = synthetic_lm_batches(cfg.vocab, args.batch, args.seq, args.seed)
    tokens_per_step = args.batch * args.seq
    t_last, ema = time.perf_counter(), None
    for i, batch in zip(range(start, args.steps), data):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "vlm":
            jb["patches"] = jnp.zeros((args.batch, cfg.n_patches, cfg.d_model),
                                      jnp.float32)
        if cfg.family == "audio":
            jb["frames"] = jnp.zeros((args.batch, cfg.n_audio_frames,
                                      cfg.d_model), jnp.float32)
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        if (i + 1) % args.log_every == 0 or i == start:
            loss = float(metrics["loss"])
            dt = (time.perf_counter() - t_last) / (args.log_every if i > start else 1)
            t_last = time.perf_counter()
            ema = loss if ema is None else 0.9 * ema + 0.1 * loss
            print(f"step {i + 1:5d}  loss {loss:.4f}  ema {ema:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  gnorm "
                  f"{float(metrics['grad_norm']):.2f}  "
                  f"{tokens_per_step / max(dt, 1e-9):,.0f} tok/s", flush=True)
        if saver and (i + 1) % args.ckpt_every == 0:
            saver({"params": params, "opt": opt_state}, i + 1)
    if saver:
        saver({"params": params, "opt": opt_state}, args.steps)
        saver.wait()
        print(f"checkpointed to {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
