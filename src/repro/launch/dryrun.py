import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells, single-pod
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # 2-pod 512-chip mesh
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k -v

Writes JSON rows to --out (default benchmarks/out/dryrun_<mesh>.json).
Compile-only: no device buffers are ever allocated (ShapeDtypeStructs +
eval_shape throughout).
"""
import argparse
import json
import sys
import time
import traceback

import jax


def _compile_once(cfg, shape, mesh, layer_unroll):
    from repro.launch.steps import build_cell

    cell = build_cell(cfg, shape, mesh, layer_unroll=layer_unroll)
    t0 = time.time()
    # production buffer reuse: decode/prefill update the cache in place,
    # train updates params/optimizer in place
    donate = (2,) if cell.kind in ("prefill", "decode") else (0, 1)
    with jax.set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return compiled, time.time() - t0


def run_cell(cfg, shape, mesh, mesh_name, verbose=False, extrapolate=True):
    """Compile (u=1) — the production lowering — and, when
    ``extrapolate``, also u=2 to back out per-layer cost: a scan body is
    counted once by cost analysis, so  true = c1 + (trips−1)·(c2−c1)."""
    from repro.launch.roofline import analyze_compiled, extrapolate_report
    from repro.launch.steps import scan_trips

    compiled1, t1 = _compile_once(cfg, shape, mesh, 1)
    mem = compiled1.memory_analysis()
    rep = analyze_compiled(compiled1, cfg, shape, mesh_name, mesh.size)
    t2 = 0.0
    if extrapolate and scan_trips(cfg) > 1:
        compiled2, t2 = _compile_once(cfg, shape, mesh, 2)
        rep2 = analyze_compiled(compiled2, cfg, shape, mesh_name, mesh.size)
        rep = extrapolate_report(rep, rep2, scan_trips(cfg))
    row = rep.row()
    row.update({"compile_s": round(t1 + t2, 1), "status": "ok",
                "temp_bytes_gib": round(rep.temp_bytes / 2**30, 2),
                "arg_bytes_gib": round(rep.argument_bytes / 2**30, 2)})
    if verbose:
        print(f"  memory_analysis(u=1): {mem}")
        print(f"  extrapolated flops/dev={row['flops/dev']:.3e} "
              f"bytes/dev={row['bytes/dev']:.3e}")
        print(f"  collectives: {rep.coll}")
    return row


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="single arch id (default: all)")
    p.add_argument("--shape", default=None, help="single shape (default: all)")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default=None)
    p.add_argument("--no-extrapolate", action="store_true",
                   help="single u=1 compile per cell (the multi-pod pass "
                        "only proves the pod axis shards; the roofline "
                        "table is single-pod)")
    p.add_argument("-v", "--verbose", action="store_true")
    args = p.parse_args(argv)

    from repro.configs import ARCHS, SHAPES, skip_reason
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    rows, failures = [], []
    for a in archs:
        cfg = ARCHS[a]
        for s in shapes:
            shape = SHAPES[s]
            reason = skip_reason(cfg, shape)
            tag = f"{a} × {s} × {mesh_name}"
            if reason:
                print(f"[skip] {tag}: {reason}")
                rows.append({"arch": a, "shape": s, "mesh": mesh_name,
                             "status": "skip", "reason": reason})
                continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                row = run_cell(cfg, shape, mesh, mesh_name, args.verbose,
                               extrapolate=not args.no_extrapolate)
                rows.append(row)
                print(f"  ok: compile {row['compile_s']}s "
                      f"bottleneck={row['bottleneck']} step={row['step_s']:.4f}s "
                      f"mfu={row['mfu']:.3f} "
                      f"temp={row['temp_bytes_gib']}GiB", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append(tag)
                rows.append({"arch": a, "shape": s, "mesh": mesh_name,
                             "status": "fail", "error": str(e)[:500]})
                print(f"  FAIL: {e}", flush=True)
                if args.verbose:
                    traceback.print_exc()

    out = args.out or f"benchmarks/out/dryrun_{mesh_name}.json"
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # merge into existing rows (single-cell reruns update their cell only)
    merged = {}
    if os.path.exists(out):
        try:
            with open(out) as f:
                for r in json.load(f):
                    merged[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
        except (json.JSONDecodeError, OSError):
            pass
    for r in rows:
        merged[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    rows_out = list(merged.values())
    with open(out, "w") as f:
        json.dump(rows_out, f, indent=1)
    print(f"\nwrote {len(rows)} rows ({len(rows_out)} total) to {out}")
    if failures:
        print(f"FAILED cells: {failures}")
        return 1
    print("all cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
