"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

``cost_analysis()`` on the compiled SPMD module reports *per-device*
FLOPs and bytes, so no division by chip count is needed. Collective
bytes are parsed from the optimized HLO: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we sum the
per-device *wire bytes* under ring-algorithm accounting:

    all-gather       (N−1)/N · output_bytes
    reduce-scatter   (N−1)/N · input_bytes
    all-reduce       2·(N−1)/N · input_bytes   (RS + AG)
    all-to-all       (N−1)/N · input_bytes
    collective-permute  input_bytes

Raw operand bytes are also reported (``operand_bytes``) for the simple
"sum operand sizes" view. Hardware constants: TPU v5e — 197 TFLOP/s
bf16, 819 GB/s HBM, 50 GB/s/link ICI (one link assumed active; v5e has
multiple axes, so this is conservative).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

__all__ = ["RooflineReport", "analyze_compiled", "collective_bytes",
           "extrapolate_report"]

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)(.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        return max(1, first.count(",") + 1)
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (+ raw operand bytes)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0,
           "operand_bytes": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shape, kind, operands, _tail = m.groups()
        n = _group_size(line)
        in_bytes = _shape_bytes(operands)
        out_bytes = _shape_bytes(out_shape)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-gather":
            wire = frac * out_bytes
        elif kind == "all-reduce":
            wire = 2.0 * frac * in_bytes
        elif kind == "reduce-scatter":
            wire = frac * in_bytes
        elif kind == "all-to-all":
            wire = frac * in_bytes
        else:  # collective-permute
            wire = float(in_bytes)
        out[kind] += wire
        out["operand_bytes"] += in_bytes
        out["count"] += 1
    out["wire_bytes"] = (out["all-gather"] + out["all-reduce"]
                         + out["reduce-scatter"] + out["all-to-all"]
                         + out["collective-permute"])
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll: Dict[str, float]
    argument_bytes: int
    temp_bytes: int
    output_bytes: int
    model_flops_total: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll["wire_bytes"] / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs·chips) — remat/redundancy waste."""
        total_hlo = self.flops_per_device * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Roofline-model FLOP utilization: useful FLOPs / (chips · peak
        · step_time)."""
        denom = self.chips * PEAK_FLOPS * self.step_s
        return self.model_flops_total / denom if denom else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops/dev": self.flops_per_device,
            "bytes/dev": self.bytes_per_device,
            "coll_wire_bytes/dev": self.coll["wire_bytes"],
            "coll_ops": self.coll["count"],
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck, "step_s": self.step_s,
            "useful_flops_frac": self.useful_flops_frac, "mfu": self.mfu,
            "arg_bytes/dev": self.argument_bytes,
            "temp_bytes/dev": self.temp_bytes,
        }


def scan_hidden_flops(cfg, shape, chips: int, attn_chunk: int = 1024) -> float:
    """Per-device FLOPs that XLA's cost analysis misses because they sit
    inside ``lax.scan`` bodies that are counted once.

    With layers unrolled (the dry-run default) two scan families remain:
      * the q-chunked attention scan (nc = S/chunk bodies, 1 counted) —
        the dominant correction at long S;
      * SSM/WKV time recurrences (S bodies, 1 counted) — small (<1% of
        layer FLOPs) but included.

    Returned value is the *missing* amount to add to cost_analysis
    FLOPs; backward of a rematted scan ≈ 2× forward, so train cells
    scale the correction by 3.
    """
    b, s = shape.global_batch, shape.seq_len
    train_mult = 3.0 if shape.kind == "train" else 1.0
    if shape.kind == "decode":
        s_q = 1
    else:
        s_q = s
    missing = 0.0
    # --- chunked-attention correction (full rectangle, masked) ---
    def attn_missing(n_layers, heads, kv_len):
        if s_q <= attn_chunk or s_q % attn_chunk:
            return 0.0
        nc = s_q // attn_chunk
        full = 4.0 * b * s_q * kv_len * heads * cfg.hd * n_layers
        return full * (nc - 1) / nc

    if cfg.family in ("dense", "vlm", "moe"):
        missing += attn_missing(cfg.n_layers, cfg.n_heads, s_q)
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.shared_attn_every
        missing += attn_missing(n_attn, cfg.n_heads, s_q)
        # SSD recurrence: per step ~6·B·nh·hd·ds flops, S steps, 1 counted
        nh = cfg.inner // cfg.ssm_head_dim
        missing += (6.0 * b * nh * cfg.ssm_head_dim * cfg.ssm_state
                    * max(s_q - 1, 0) * cfg.n_layers)
    elif cfg.family == "ssm":
        # WKV recurrence: ~6·B·H·hd² per step
        missing += (6.0 * b * cfg.n_heads * cfg.hd * cfg.hd
                    * max(s_q - 1, 0) * cfg.n_layers)
    elif cfg.family == "audio":
        missing += attn_missing(cfg.n_layers + cfg.n_encoder_layers,
                                cfg.n_heads, s_q)
    return train_mult * missing / chips


def model_flops(cfg, shape) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D for prefill, 2·N·B for one
    decode step; N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def extrapolate_report(r1: RooflineReport, r2: RooflineReport,
                       trips: int) -> RooflineReport:
    """Two-point scan extrapolation: XLA cost analysis counts a scan body
    once, so with partial-unroll factors u=1, u=2:

        cost(u) = fixed + u · per_layer  →  true = c1 + (trips−1)·(c2−c1)

    Applied to FLOPs (minus the analytic scan-hidden part, which is
    already per-trip-corrected), bytes, and every collective bucket.
    Memory-analysis numbers stay from the u=1 (production) lowering.
    """
    k = trips - 1

    def ex(a, b):
        return a + k * max(b - a, 0.0)

    # (the analytic scan-hidden FLOPs are identical in r1 and r2, so they
    # cancel in the delta and survive exactly once in the base term)
    coll = {key: (ex(r1.coll[key], r2.coll[key])
                  if isinstance(r1.coll[key], float) else r1.coll[key])
            for key in r1.coll}
    coll["count"] = r1.coll["count"]
    return RooflineReport(
        arch=r1.arch, shape=r1.shape, mesh=r1.mesh, chips=r1.chips,
        flops_per_device=ex(r1.flops_per_device, r2.flops_per_device),
        bytes_per_device=ex(r1.bytes_per_device, r2.bytes_per_device),
        coll=coll,
        argument_bytes=r1.argument_bytes,
        temp_bytes=r1.temp_bytes,
        output_bytes=r1.output_bytes,
        model_flops_total=r1.model_flops_total,
    )


def analyze_compiled(compiled, cfg, shape, mesh_name: str,
                     chips: int) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    hidden = scan_hidden_flops(cfg, shape, chips)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=float(ca.get("flops", 0.0)) + hidden,
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        coll=coll,
        argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        model_flops_total=model_flops(cfg, shape),
    )
