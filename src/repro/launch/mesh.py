"""Production meshes.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the ``pod``
axis carries only data parallelism (gradient all-reduce crosses the DCI;
everything bandwidth-hungry stays on intra-pod ICI).

Functions, not module constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* first jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
