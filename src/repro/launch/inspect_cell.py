import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb microscope: compile one cell and attribute memory.

    PYTHONPATH=src python -m repro.launch.inspect_cell --arch rwkv6-7b \\
        --shape train_4k [--layers 2] [--act-shard d]

Prints per-argument sharded sizes (catches unsharded params), the top
HLO buffers, and the collective breakdown.
"""
import argparse
import re

import jax
import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--layers", type=int, default=0, help="override n_layers")
    p.add_argument("--act-shard", default=None)
    p.add_argument("--unroll", type=int, default=1)
    p.add_argument("--top", type=int, default=12)
    args = p.parse_args(argv)

    from repro.configs import ARCHS, SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze_compiled, collective_bytes
    from repro.launch.steps import build_cell

    cfg = ARCHS[args.arch]
    if args.layers:
        kw = {"n_layers": args.layers}
        if cfg.family == "hybrid":
            kw["n_layers"] = max(args.layers // cfg.shared_attn_every, 1) \
                * cfg.shared_attn_every
        cfg = cfg.replace(**kw)
    if args.act_shard:
        cfg = cfg.replace(act_shard=args.act_shard)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh()
    cell = build_cell(cfg, shape, mesh, layer_unroll=args.unroll)

    # ---- per-argument sharded bytes (top offenders) ----
    print("== largest per-device argument shards ==")
    entries = []

    def visit(path, leaf, sh):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        nshards = sh.num_devices_sharded if hasattr(sh, "num_devices_sharded") else None
        try:
            frac = np.prod([leaf.shape[i] for i in range(len(leaf.shape))])
            shard_shape = sh.shard_shape(leaf.shape)
            per_dev = int(np.prod(shard_shape)) * leaf.dtype.itemsize
        except Exception:
            per_dev = nbytes
        entries.append((per_dev, nbytes, jax.tree_util.keystr(path), str(sh.spec)))

    for arg, shardings in zip(cell.args, cell.in_shardings):
        flat, _ = jax.tree_util.tree_flatten_with_path(arg)
        sflat = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        for (path, leaf), sh in zip(flat, sflat):
            visit(path, leaf, sh)
    entries.sort(reverse=True)
    for per_dev, total, path, spec in entries[: args.top]:
        print(f"  {per_dev / 2**20:10.1f} MiB/dev (total {total / 2**30:6.2f} GiB) "
              f"{path}  spec={spec}")

    # ---- compile ----
    donate = (2,) if cell.kind in ("prefill", "decode") else (0, 1)
    with jax.set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=donate)
        compiled = jitted.lower(*cell.args).compile()
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    print(f"\n== compiled ==  temp={mem.temp_size_in_bytes / 2**30:.2f} GiB  "
          f"args={mem.argument_size_in_bytes / 2**30:.2f} GiB  "
          f"flops/dev={ca.get('flops', 0):.3e}  "
          f"bytes/dev={ca.get('bytes accessed', 0):.3e}")

    txt = compiled.as_text()
    print("\n== top HLO buffer shapes ==")
    pat = re.compile(r"(bf16|f32|f16|s32|u32|s8|pred)\[([\d,]+)\]")
    sizes = {}
    counts = {}
    for m in pat.finditer(txt):
        dims = [int(x) for x in m.group(2).split(",")]
        byt = int(np.prod(dims)) * {"bf16": 2, "f16": 2, "f32": 4, "s32": 4,
                                    "u32": 4, "s8": 1, "pred": 1}[m.group(1)]
        key = f"{m.group(1)}[{m.group(2)}]"
        sizes[key] = byt
        counts[key] = counts.get(key, 0) + 1
    for k, byt in sorted(sizes.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"  {byt / 2**20:10.1f} MiB  ×{counts[k]:3d}  {k}")

    print("\n== collectives ==")
    print("  ", collective_bytes(txt))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
