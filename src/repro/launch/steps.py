"""Cell builder: (arch config × input shape × mesh) → a jit-able step
function + abstract inputs + in_shardings. Shared by the dry-run, the
roofline harness and the real drivers.

Nothing here allocates device memory: params/optimizer/cache shapes come
from ``jax.eval_shape`` and inputs are ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import sharding as shd
from ..configs import Shape, input_specs
from ..models import get_model
from ..models.config import ModelConfig
from ..train.optimizer import AdamWConfig, adamw_init
from ..train.train_step import make_train_step

__all__ = ["Cell", "build_cell"]

_BIG_PARAMS = 10_000_000_000  # bf16 Adam moments above this (fits 16 GiB)


@dataclass
class Cell:
    fn: Callable                 # jit-able step
    args: Tuple[Any, ...]        # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any           # matches fn's output pytree (None = auto)
    kind: str                    # train | prefill | decode
    cfg: ModelConfig
    shape: Shape


def _opt_config(cfg: ModelConfig) -> AdamWConfig:
    big = cfg.param_count() > _BIG_PARAMS
    return AdamWConfig(moment_dtype="bfloat16" if big else "float32")


def scan_trips(cfg: ModelConfig) -> int:
    """Trip count of the (outer) layer scan — the extrapolation factor
    for two-point cost analysis."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every
    return cfg.n_layers


def build_cell(cfg: ModelConfig, shape: Shape, mesh: Mesh,
               layer_unroll: int = 1) -> Cell:
    """``layer_unroll`` sets the partial unroll factor of the layer scan;
    the dry-run compiles u=1 and u=2 and extrapolates per-layer cost
    (XLA cost analysis counts a scan body exactly once)."""
    cfg = cfg.replace(layer_unroll=layer_unroll)
    mod = get_model(cfg)
    batch_abs = input_specs(cfg, shape)
    if cfg.act_shard == "full_dp" and shape.global_batch % mesh.size == 0:
        bspec = shd._filter_spec((shd.DP_AXES + (shd.TP_AXIS,),),
                                 tuple(mesh.axis_names))
        dp_div = mesh.size
    else:
        bspec = shd.batch_spec(mesh)
        dp_div = _dp(mesh)
    batch_shardings = {
        k: NamedSharding(mesh, bspec if v.shape[0] % dp_div == 0
                         else P())
        for k, v in batch_abs.items()
    }
    params_abs = jax.eval_shape(
        functools.partial(mod.init, cfg), jax.random.key(0))
    params_sh = shd.param_shardings(params_abs, mesh)

    if shape.kind == "train":
        opt = _opt_config(cfg)
        step = make_train_step(cfg, opt)
        opt_abs = jax.eval_shape(
            functools.partial(adamw_init, cfg=opt), params_abs)
        opt_sh = {
            "m": shd.param_shardings(opt_abs["m"], mesh),
            "v": shd.param_shardings(opt_abs["v"], mesh),
            "step": NamedSharding(mesh, P()),
        }
        # params/opt come back with their own shardings; metrics replicate
        return Cell(step, (params_abs, opt_abs, batch_abs),
                    (params_sh, opt_sh, batch_shardings),
                    (params_sh, opt_sh, None),
                    "train", cfg, shape)

    b = shape.global_batch
    # vlm prefill writes patch-prefix KV too; whisper/ssm caches ignore it
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    cache_abs = jax.eval_shape(
        lambda: mod.init_cache(cfg, b, shape.seq_len + extra))
    cache_sh = shd.cache_shardings(cache_abs, mesh, b)

    if shape.kind == "prefill":
        def prefill(params, batch, cache):
            return mod.prefill(params, batch, cfg, cache)
        return Cell(prefill, (params_abs, batch_abs, cache_abs),
                    (params_sh, batch_shardings, cache_sh),
                    (None, cache_sh),   # cache stays sharded like the input
                    "prefill", cfg, shape)

    def decode(params, tokens, cache):
        return mod.decode_step(params, tokens, cache, cfg)

    tok_abs = batch_abs["tokens"]
    tok_sh = batch_shardings["tokens"]
    return Cell(decode, (params_abs, tok_abs, cache_abs),
                (params_sh, tok_sh, cache_sh),
                (None, cache_sh),
                "decode", cfg, shape)


def _dp(mesh: Mesh) -> int:
    axes = tuple(mesh.axis_names)
    return int(np.prod([mesh.shape[a] for a in shd.DP_AXES if a in axes])) or 1
