"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d_model=2560 + ONE shared
attention block (32H MHA, d_ff=10240) applied every 6 layers;
ssm_state=64, vocab=32000. [arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B]

Mamba2 state is O(1) in context → qualifies for long_500k."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    d_inner=5120,
    shared_attn_every=6,
    subquadratic=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    act_shard="seq",
)
