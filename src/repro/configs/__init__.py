"""Config registry: ``--arch <id>`` → ModelConfig, plus input_specs and
reduced smoke-test configs."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from . import (granite_moe_1b_a400m, llama3_2_3b, phi_3_vision_4_2b,
               qwen1_5_4b, qwen3_4b, qwen3_moe_235b_a22b, rwkv6_7b,
               smollm_360m, whisper_base, zamba2_2_7b)
from .shapes import SHAPES, Shape, applicable, skip_reason  # noqa: F401

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (llama3_2_3b, qwen3_4b, qwen1_5_4b, smollm_360m,
              qwen3_moe_235b_a22b, granite_moe_1b_a400m, phi_3_vision_4_2b,
              rwkv6_7b, zamba2_2_7b, whisper_base)
}

__all__ = ["ARCHS", "SHAPES", "get_config", "reduced", "input_specs",
           "applicable", "skip_reason"]


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}") from None


def reduced(cfg: ModelConfig, vocab: int = 512) -> ModelConfig:
    """Same-family shrunken config for CPU smoke tests: few layers, small
    width/experts/tables, full code path."""
    kw = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4),
        head_dim=16, d_ff=min(cfg.d_ff, 128) if cfg.d_ff else 0,
        vocab=vocab, param_dtype="float32", compute_dtype="float32",
        remat=False,
    )
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4
    else:
        kw["n_kv_heads"] = 2
    if cfg.family == "moe":
        kw.update(num_experts=min(cfg.num_experts, 8), top_k=min(cfg.top_k, 2),
                  d_expert=32, capacity_factor=8.0)
    if cfg.family == "ssm":
        kw.update(n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, shared_attn_every=2, ssm_state=16,
                  ssm_head_dim=16, d_inner=128, d_ff=128)
    if cfg.family == "audio":
        kw.update(n_encoder_layers=2, n_audio_frames=8)
    if cfg.family == "vlm":
        kw.update(n_patches=4)
    return cfg.replace(**kw)


def input_specs(cfg: ModelConfig, shape: Shape, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for every model input of one cell —
    weak-type-correct, shardable, no device allocation.

    train  → {'tokens': (B, S), 'labels': (B, S)} (+ modality extras)
    prefill→ {'tokens': (B, S)} (+ extras); cache built separately
    decode → {'tokens': (B, 1)}; cache of length S built separately
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s), dtype), "labels": sds((b, s), dtype)}
    elif shape.kind == "prefill":
        batch = {"tokens": sds((b, s), dtype)}
    else:
        batch = {"tokens": sds((b, 1), dtype)}
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patches"] = sds((b, cfg.n_patches, cfg.d_model), f32)
    if cfg.family == "audio" and shape.kind != "decode":
        batch["frames"] = sds((b, cfg.n_audio_frames, cfg.d_model), f32)
    return batch
