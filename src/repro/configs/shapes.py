"""Input-shape set assigned to the LM architectures.

    train_4k      seq 4,096   global_batch 256   → train_step
    prefill_32k   seq 32,768  global_batch 32    → prefill (serve)
    decode_32k    seq 32,768  global_batch 128   → decode_step (one new
                                                   token, 32k KV cache)
    long_500k     seq 524,288 global_batch 1     → decode_step; requires
                                                   sub-quadratic decode
                                                   state (SSM/hybrid only)

``applicable(cfg, shape)`` encodes the skip rules (see DESIGN.md
§Arch-applicability): long_500k is skipped for pure full-attention archs
(a 512k dense-KV decode is the quadratic-prefill regime the shape
excludes); every other cell runs for all 10 archs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from ..models.config import ModelConfig

__all__ = ["Shape", "SHAPES", "applicable", "skip_reason"]


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: Shape) -> bool:
    return skip_reason(cfg, shape) is None


def skip_reason(cfg: ModelConfig, shape: Shape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: 512k dense-KV decode is the "
                "quadratic regime long_500k excludes (DESIGN.md "
                "§Arch-applicability)")
    return None
