"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attention-free, 64 heads of
size 64) d_ff=14336 vocab=65536, data-dependent decay.
[arXiv:2404.05892; hf:RWKV/v6-Finch-7B-HF]

O(1)-state decode → qualifies for the long_500k shape."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # wkv heads = d_model / head_size(64)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    subquadratic=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    act_shard="full_dp",
)
