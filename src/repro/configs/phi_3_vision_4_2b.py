"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend (STUB: input_specs feeds
precomputed (B, n_patches, d_model) patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    rope_theta=10_000.0,
    n_patches=576,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    act_shard="seq",
)
