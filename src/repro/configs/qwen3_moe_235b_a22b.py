"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, MoE 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-235B-A22B; hf]

The big dry-run target: bf16 params + bf16 Adam moments + FSDP×EP
sharding are what make it fit 16 GiB/chip (EXPERIMENTS.md §Dry-run).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    qk_norm=True,
    num_experts=128,
    top_k=8,
    d_expert=1536,
    rope_theta=1_000_000.0,
    rms_eps=1e-6,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    act_shard="seq",
)
