"""whisper-base [audio] — enc-dec 6L+6L d_model=512 8H d_ff=2048
vocab=51865; conv/log-mel frontend is a STUB (input_specs feeds
precomputed (B, 1500, d_model) frame embeddings). [arXiv:2212.04356]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51865,
    n_encoder_layers=6,
    n_audio_frames=1500,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    act_shard="seq",
)
