"""Pallas TPU kernel: flash attention (streaming softmax), GQA-aware.

The serving path's prefill hot spot. Grid is (B·H, q_tiles, kv_tiles)
with the kv axis innermost; VMEM scratch carries the running max (m),
normalizer (l) and output accumulator across kv tiles — the standard
TPU formulation of FlashAttention's online softmax.

Causal jobs skip fully-masked kv tiles structurally: the body runs only
under ``pl.when(j·bk < (i+1)·bq)`` and finalization fires at the last
*valid* kv tile of each q tile, halving compute for causal prefill.

GQA without materializing repeated KV: the K/V BlockSpec index_map
derives the kv-head row from the q-head grid index
(``batch·KVH + (qh // group)``), so a (B·KVH, S, D) cache is read
directly — no (B·H, S, D) broadcast copy in HBM.

VMEM per step (f32, hd=128, 512/512 tiles): q 256 KiB + k,v 512 KiB +
acc/o 256 KiB + s/p 1 MiB ≈ 2.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            num_k_tiles: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        valid = (j * block_k) < ((i + 1) * block_q)
        last_j = jnp.minimum(
            num_k_tiles - 1, ((i + 1) * block_q - 1) // block_k)
    else:
        valid = True
        last_j = num_k_tiles - 1

    @pl.when(valid)
    def _body():
        q = q_ref[0]                                     # (bq, hd)
        k = k_ref[0]                                     # (bk, hd)
        v = v_ref[0]                                     # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, _NEG)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        if causal:
            p = jnp.where(s <= _NEG / 2, 0.0, p)  # fully-masked entries
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(j == last_j)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret",
                     "num_kv_heads"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    num_kv_heads: int | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, KVH, S, D) with H % KVH == 0.

    Returns (B, H, S, D). S is padded to tile multiples internally (padded
    keys are masked out by the causal/row-validity logic: padded q rows
    produce garbage rows that are sliced off; padded k cols are excluded
    by masking ``cols < S``)."""
    b, h, s, d = q.shape
    kvh = num_kv_heads or k.shape[1]
    group = h // kvh
    if scale is None:
        scale = d ** -0.5
    bq = min(block_q, max(128, 1 << (s - 1).bit_length() if s < 128 else 128)) \
        if s < block_q else block_q
    bk = min(block_k, bq) if s < block_k else block_k
    sp = -(-s // bq) * bq
    sp = -(-sp // bk) * bk
    qp = jnp.zeros((b * h, sp, d), q.dtype).at[:, :s].set(q.reshape(b * h, s, d))
    kp = jnp.zeros((b * kvh, sp, d), k.dtype).at[:, :s].set(k.reshape(b * kvh, s, d))
    vp = jnp.zeros((b * kvh, sp, d), v.dtype).at[:, :s].set(v.reshape(b * kvh, s, d))
    if not causal and sp != s:
        # Mask padded keys via a causal=False-safe trick: zero-length keys
        # would need an explicit mask; simplest is to fall back to an
        # s-multiple requirement for non-causal jobs.
        raise ValueError("non-causal flash requires S % block_k == 0")

    nq, nk = sp // bq, sp // bk
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=bq, block_k=bk,
        num_k_tiles=nk)

    def kv_index(bh, i, j):
        return ((bh // h) * kvh + (bh % h) // group, j, 0)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s].reshape(b, h, s, d)
