"""Pallas TPU kernel: tiled pair-similarity — the paper's reduce-phase
hot spot (§III-A: "the reduce phase consumes ... more than 95% of the
overall runtime").

A match task (BlockSplit tile or PairRange range segment) reduces to
scoring A @ Bᵀ over two strips of the entity-feature matrix — pure MXU
work once titles are encoded as L2-normalized n-gram vectors
(er/encode.py). The kernel tiles (M, N) into (block_m, block_n) MXU-
aligned tiles chosen from the autotuning lattice ``GEOMETRY_LATTICE``
(er/compiler/tune.py picks per-catalog geometry from the block-size
histogram); each grid step keeps one (block_m, d) LHS strip and one
(block_n, d) RHS strip in VMEM, computes the dot, applies the threshold,
and the entry's validity window / triangular mask / corner cuts via
global row/col indices.

The catalog kernels stream their strips through *double-buffered* manual
DMA: inputs stay in HBM (``memory_space=ANY``); two-deep VMEM strip
buffers prefetch tile t+1's LHS/RHS strips while tile t computes, so the
strip copy-in overlaps the MXU work instead of serializing ahead of it.

VMEM per step, double-buffered (f32, d feature dim):
  strips   2 · (bm + bn) · d · 4 B          (two slots each side)
  compute  ≈ 4 · bm · bn · 4 B              (scores, mask, dest, flat)
  epilogue (compact only)
           (bm² + bn² + capacity · bn + capacity) · 4 B
Worst lattice candidate (bm = bn = 256, d = 256, capacity = 1024):
  2·(512)·256·4 ≈ 1.0 MiB strips + 1.0 MiB compute + 1.3 MiB epilogue
  ≈ 3.3 MiB — under the ``VMEM_BUDGET_BYTES`` bound asserted at lowering
time by :func:`check_vmem` (the ~16 MiB/core physical budget minus
headroom for compiler temporaries). :func:`catalog_vmem_bytes` is the
shared model; er/compiler/tune.py filters lattice candidates with it.

Two entry points:
  * :func:`pair_scores` — dense (M, N) scoring of two full matrices
    (kept as the simple test target and the building block the dense
    benchmarks use).
  * :func:`pair_scores_catalog` — the *tile-catalog* variant driving the
    fused plan executor (er/executor.py, DESIGN.md §Catalog): the grid is
    one-dimensional over catalog entries; a scalar-prefetch operand (the
    catalog, SMEM) feeds the strip DMAs so each grid step pulls the two
    feature strips named by the current entry — the same pattern
    grouped_mm.py uses for expert tiles. The kernel applies the entry's
    validity window, triangular mask and PairRange corner cuts in-kernel
    and writes a per-tile survivor mask; the host compacts survivors and
    runs the exact verifier only on them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pair_scores", "pair_scores_catalog",
           "pair_scores_catalog_compact", "catalog_tile_mask", "NCOLS",
           "GEOMETRY_LATTICE", "VMEM_BUDGET_BYTES", "catalog_vmem_bytes",
           "check_vmem"]

# Catalog entry layout (int32 columns) — shared with er/executor.py and
# kernels/ref.py. Rows/cols below are *global* row indices of the feature
# matrices; a tile covers rows [a_tile·bm, (a_tile+1)·bm) × cols
# [b_tile·bn, (b_tile+1)·bn).
#   0 a_tile   LHS strip index (units of block_m)
#   1 b_tile   RHS strip index (units of block_n)
#   2 r0, 3 r1 valid row window [r0, r1)   (task bounds)
#   4 c0, 5 c1 valid col window [c0, c1)
#   6 tri      1 → keep only row < col (intra-block tasks)
#   7 lb_r, 8 lb_c   lower corner cut: keep (row > lb_r) | (col >= lb_c)
#   9 ub_r, 10 ub_c  upper corner cut: keep (row < ub_r) | (col <= ub_c)
#  11 band     > 0 → keep only col − row < band (Sorted Neighborhood's
#              window-w diagonal band, band = w; 0 = unconstrained)
#  12 reducer  owning reduce task (host-side attribution / device routing)
NCOLS = 13

# MXU-aligned (block_m, block_n) candidates the tile-geometry autotuner
# (er/compiler/tune.py) sweeps. Finite and static: a resident service
# compiles at most |lattice| kernel variants during warmup, then pins
# the winner — the zero-steady-state-recompile contract holds.
GEOMETRY_LATTICE = ((32, 32), (32, 64), (32, 128), (32, 256),
                    (64, 32), (64, 64), (64, 128), (64, 256),
                    (128, 32), (128, 64), (128, 128), (128, 256),
                    (256, 32), (256, 64), (256, 128), (256, 256))

# ~16 MiB/core physical VMEM minus headroom for Mosaic temporaries.
VMEM_BUDGET_BYTES = 12 * 2 ** 20


def catalog_vmem_bytes(block_m: int, block_n: int, d: int,
                       capacity: int = 0) -> int:
    """Worst-case VMEM bytes one grid step of the catalog kernels holds
    live: double-buffered strips + compute planes (+ compaction epilogue
    when ``capacity`` > 0). Shared with er/compiler/tune.py, which drops
    lattice candidates this model puts over ``VMEM_BUDGET_BYTES``."""
    strips = 2 * (block_m + block_n) * d * 4
    compute = 4 * block_m * block_n * 4
    epilogue = 0
    if capacity:
        epilogue = (block_m * block_m + block_n * block_n
                    + capacity * block_n + capacity) * 4
    return strips + compute + epilogue


def check_vmem(block_m: int, block_n: int, d: int, capacity: int = 0) -> None:
    """Lowering-time guard: raise before tracing a kernel whose step
    working set cannot fit VMEM."""
    need = catalog_vmem_bytes(block_m, block_n, d, capacity)
    if need > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"tile geometry ({block_m}, {block_n}) with d={d}"
            f"{f', capacity={capacity}' if capacity else ''} needs "
            f"{need} B VMEM per step > budget {VMEM_BUDGET_BYTES} B")


def catalog_tile_mask(entry, gi, gj):
    """The membership predicate of one catalog entry, shared by the Pallas
    kernel and the XLA reference. ``entry`` holds the NCOLS int32 scalars,
    ``gi``/``gj`` the (bm, bn) global row/col index grids."""
    keep = (gi >= entry[2]) & (gi < entry[3])
    keep &= (gj >= entry[4]) & (gj < entry[5])
    keep &= (entry[6] == 0) | (gi < gj)
    keep &= (gi > entry[7]) | (gj >= entry[8])
    keep &= (gi < entry[9]) | (gj <= entry[10])
    keep &= (entry[11] == 0) | (gj - gi < entry[11])
    return keep


def _kernel(a_ref, b_ref, o_ref, *, threshold: float, triangular: bool,
            block_m: int, block_n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    a = a_ref[...]                       # (block_m, d)
    b = b_ref[...]                       # (block_n, d)
    s = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (block_m, block_n) MXU
    keep = s >= threshold
    if triangular:
        rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keep = keep & (rows < cols)
    o_ref[...] = jnp.where(keep, s, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "triangular", "block_m", "block_n", "interpret"))
def pair_scores(a, b, *, threshold: float = 0.8, triangular: bool = False,
                block_m: int = 128, block_n: int = 128,
                interpret: bool = False):
    """Thresholded similarity scores of every (row of a) × (row of b).

    a: (M, d), b: (N, d) — rows L2-normalized. Returns (M, N) f32 with 0
    where score < threshold (or masked by x < y when ``triangular``).
    M, N are padded to tile multiples internally.
    """
    m, d = a.shape
    n = b.shape[0]
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    a_p = jnp.zeros((mp, d), a.dtype).at[:m].set(a)
    b_p = jnp.zeros((np_, d), b.dtype).at[:n].set(b)

    out = pl.pallas_call(
        functools.partial(
            _kernel, threshold=threshold, triangular=triangular,
            block_m=block_m, block_n=block_n),
        grid=(mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Catalog kernels: double-buffered strip DMA
# ---------------------------------------------------------------------------

def _strip_dma(pltpu, cat_ref, hbm, buf, sem, slot, idx, col, blk):
    """Async copy of the ``blk``-row strip named by catalog column ``col``
    of entry ``idx`` from HBM into scratch slot ``slot``."""
    return pltpu.make_async_copy(
        hbm.at[pl.ds(cat_ref[idx, col] * blk, blk), :],
        buf.at[slot], sem.at[slot])


def _load_strips(cat_ref, a_hbm, b_hbm, a_buf, b_buf, a_sem, b_sem,
                 block_m: int, block_n: int):
    """The double-buffer schedule shared by both catalog kernels: kick
    off entry t+1's strip DMAs into slot (t+1) % 2, then wait on slot
    t % 2 (started by step t−1; by step t itself at the grid edge) and
    return this entry's (block_m, d) / (block_n, d) strips. Safe because
    the TPU grid is sequential: slot s is only overwritten two steps
    after the step that computed from it."""
    from jax.experimental.pallas import tpu as pltpu

    t = pl.program_id(0)
    nt = pl.num_programs(0)
    slot = jax.lax.rem(t, 2)
    nxt = jax.lax.rem(t + 1, 2)

    def start(s, idx):
        _strip_dma(pltpu, cat_ref, a_hbm, a_buf, a_sem, s, idx, 0,
                   block_m).start()
        _strip_dma(pltpu, cat_ref, b_hbm, b_buf, b_sem, s, idx, 1,
                   block_n).start()

    @pl.when(t == 0)
    def _():                              # warm-up: nobody prefetched t=0
        start(slot, t)

    @pl.when(t + 1 < nt)
    def _():                              # prefetch t+1 while t computes
        start(nxt, t + 1)

    _strip_dma(pltpu, cat_ref, a_hbm, a_buf, a_sem, slot, t, 0,
               block_m).wait()
    _strip_dma(pltpu, cat_ref, b_hbm, b_buf, b_sem, slot, t, 1,
               block_n).wait()
    return a_buf[slot], b_buf[slot]


def _entry_keep(cat_ref, a, b, *, threshold: float, block_m: int,
                block_n: int):
    """Score the current entry's strips and apply its predicate."""
    t = pl.program_id(0)
    s = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (block_m, block_n) MXU
    entry = [cat_ref[t, c] for c in range(NCOLS)]
    gi = entry[0] * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    gj = entry[1] * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    return (s >= threshold) & catalog_tile_mask(entry, gi, gj)


def _catalog_kernel(cat_ref, a_hbm, b_hbm, o_ref, a_buf, b_buf, a_sem,
                    b_sem, *, threshold: float, block_m: int, block_n: int):
    a, b = _load_strips(cat_ref, a_hbm, b_hbm, a_buf, b_buf, a_sem, b_sem,
                        block_m, block_n)
    keep = _entry_keep(cat_ref, a, b, threshold=threshold,
                       block_m=block_m, block_n=block_n)
    o_ref[...] = keep[None].astype(jnp.float32)


def _catalog_specs(block_m: int, block_n: int, d: int, a_dtype, b_dtype):
    """HBM-resident input specs + double-buffered scratch for the catalog
    kernels: the features stay in ANY (= HBM) and the kernel pulls strips
    itself via :func:`_load_strips`."""
    from jax.experimental.pallas import tpu as pltpu

    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY)]
    scratch = [pltpu.VMEM((2, block_m, d), a_dtype),
               pltpu.VMEM((2, block_n, d), b_dtype),
               pltpu.SemaphoreType.DMA((2,)),
               pltpu.SemaphoreType.DMA((2,))]
    return in_specs, scratch


@functools.partial(
    jax.jit, static_argnames=("threshold", "block_m", "block_n", "interpret"))
def pair_scores_catalog(a, b, catalog, *, threshold: float = 0.8,
                        block_m: int = 128, block_n: int = 128,
                        interpret: bool = False):
    """Survivor masks for a flat catalog of (block_m, block_n) tiles.

    a: (M, d), b: (N, d) feature matrices (same array for single-source
    plans); catalog: (T, NCOLS) int32 — see the column layout above.
    Returns (T, block_m, block_n) f32 ∈ {0, 1}: 1 where the pair belongs
    to the entry's task AND its score passes ``threshold``.

    The catalog is the scalar-prefetch operand (SMEM); the features stay
    in HBM and each grid step's strips arrive by double-buffered manual
    DMA — entry t+1's strips are in flight while entry t's dot runs — so
    the whole plan executes as ONE pallas_call regardless of how many
    match tasks / blocks it covers, with copy-in off the critical path.
    """
    from .grouped_mm import pltpu_prefetch

    m, d = a.shape
    n = b.shape[0]
    t = catalog.shape[0]
    check_vmem(block_m, block_n, d)
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    a_p = jnp.zeros((mp, d), a.dtype).at[:m].set(a)
    b_p = jnp.zeros((np_, d), b.dtype).at[:n].set(b)

    in_specs, scratch = _catalog_specs(block_m, block_n, d,
                                       a_p.dtype, b_p.dtype)
    grid_spec = pl.GridSpec(
        grid=(t,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_m, block_n), lambda i, cat: (i, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_catalog_kernel, threshold=threshold,
                          block_m=block_m, block_n=block_n),
        grid_spec=pltpu_prefetch(grid_spec, num_scalar_prefetch=1,
                                 scratch_shapes=scratch),
        out_shape=jax.ShapeDtypeStruct((t, block_m, block_n), jnp.float32),
        interpret=interpret,
    )(catalog, a_p, b_p)


def _catalog_compact_kernel(cat_ref, a_hbm, b_hbm, packed_ref, count_ref,
                            a_buf, b_buf, a_sem, b_sem, *, threshold: float,
                            block_m: int, block_n: int, capacity: int):
    a, b = _load_strips(cat_ref, a_hbm, b_hbm, a_buf, b_buf, a_sem, b_sem,
                        block_m, block_n)
    keep = _entry_keep(cat_ref, a, b, threshold=threshold,
                       block_m=block_m, block_n=block_n)
    kf = keep.astype(jnp.float32)

    # Row-major survivor ranks without scatter/sort (neither lowers to
    # Mosaic): prefix sums become triangular-ones matmuls, MXU-native.
    # Ranks stay exact in f32 — they are integers < bm·bn ≤ 2^24.
    cc = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 1)
    upper = (cc < jj).astype(jnp.float32)          # strict upper (bn, bn)
    excl = jax.lax.dot_general(                    # within-row exclusive
        kf, upper, (((1,), (0,)), ((), ())),       # prefix of the mask
        preferred_element_type=jnp.float32)
    row_tot = jnp.sum(kf, axis=1, keepdims=True)   # (bm, 1)
    ii = jax.lax.broadcasted_iota(jnp.int32, (block_m, block_m), 0)
    rr = jax.lax.broadcasted_iota(jnp.int32, (block_m, block_m), 1)
    lower = (rr < ii).astype(jnp.float32)          # strict lower (bm, bm)
    row_off = jax.lax.dot_general(                 # rows-above totals
        lower, row_tot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (bm, 1)
    dest = jnp.where(keep, row_off + excl, -1.0)   # pack slot, −1 = dead

    li = jax.lax.broadcasted_iota(jnp.int32, keep.shape, 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, keep.shape, 1)
    flat = (li * block_n + lj).astype(jnp.float32)  # tile-local pair id

    # packed[k] = Σ_p [dest_p == k] · flat_p — a one-hot contraction per
    # row keeps the (capacity, bn) one-hot plane VMEM-resident. Slots
    # beyond the survivor count (and anything past ``capacity``) simply
    # accumulate nothing and stay 0.
    k_iota = jax.lax.broadcasted_iota(jnp.float32, (capacity, block_n), 0)

    def row(r, acc):
        d_r = jax.lax.dynamic_slice(dest, (r, 0), (1, block_n))
        v_r = jax.lax.dynamic_slice(flat, (r, 0), (1, block_n))
        onehot = (d_r == k_iota).astype(jnp.float32)   # (capacity, bn)
        return acc + jax.lax.dot_general(
            v_r, onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (1, capacity)

    acc = jax.lax.fori_loop(
        0, block_m, row, jnp.zeros((1, capacity), jnp.float32))
    packed_ref[...] = acc.astype(jnp.int32)
    count_ref[...] = jnp.sum(kf).astype(jnp.int32)[None, None]


@functools.partial(
    jax.jit, static_argnames=("threshold", "block_m", "block_n", "capacity",
                              "interpret"))
def pair_scores_catalog_compact(a, b, catalog, *, threshold: float = 0.8,
                                block_m: int = 128, block_n: int = 128,
                                capacity: int = 1024,
                                interpret: bool = False):
    """:func:`pair_scores_catalog` with an on-device survivor-compaction
    epilogue: instead of a (T, bm, bn) mask the host must ``np.nonzero``,
    each tile returns its survivors packed into ``capacity`` slots.

    Returns ``(packed, counts)``:
      * packed (T, capacity) int32 — tile-local flat pair ids
        ``i·block_n + j`` of the survivors, in row-major order; slots at
        index >= min(count, capacity) are 0.
      * counts (T, 1) int32 — the EXACT survivor count per tile, even
        when it exceeds ``capacity`` (the host detects overflow and
        falls back to the mask path; survivors past ``capacity`` are
        dropped from ``packed``).

    The epilogue is scatter-free (Mosaic has no scatter/sort): survivor
    pack slots come from prefix sums expressed as triangular-ones
    matmuls, and packing is a one-hot dot contraction — all MXU/VPU
    primitives, computed per tile while the scores are still in VMEM.
    Strips arrive by the same double-buffered DMA as the mask variant.
    """
    from .grouped_mm import pltpu_prefetch

    m, d = a.shape
    n = b.shape[0]
    t = catalog.shape[0]
    check_vmem(block_m, block_n, d, capacity)
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    a_p = jnp.zeros((mp, d), a.dtype).at[:m].set(a)
    b_p = jnp.zeros((np_, d), b.dtype).at[:n].set(b)

    in_specs, scratch = _catalog_specs(block_m, block_n, d,
                                       a_p.dtype, b_p.dtype)
    grid_spec = pl.GridSpec(
        grid=(t,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, capacity), lambda i, cat: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, cat: (i, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_catalog_compact_kernel, threshold=threshold,
                          block_m=block_m, block_n=block_n,
                          capacity=capacity),
        grid_spec=pltpu_prefetch(grid_spec, num_scalar_prefetch=1,
                                 scratch_shapes=scratch),
        out_shape=(jax.ShapeDtypeStruct((t, capacity), jnp.int32),
                   jax.ShapeDtypeStruct((t, 1), jnp.int32)),
        interpret=interpret,
    )(catalog, a_p, b_p)
