"""Pallas TPU kernel: tiled pair-similarity — the paper's reduce-phase
hot spot (§III-A: "the reduce phase consumes ... more than 95% of the
overall runtime").

A match task (BlockSplit tile or PairRange range segment) reduces to
scoring A @ Bᵀ over two strips of the entity-feature matrix — pure MXU
work once titles are encoded as L2-normalized n-gram vectors
(er/encode.py). The kernel tiles (M, N) into (block_m, block_n) MXU-
aligned tiles; each grid step keeps one (block_m, d) LHS strip and one
(d, block_n) RHS strip in VMEM, computes the dot, applies the threshold,
and optionally the x < y upper-triangular mask (intra-block tasks, k.i /
unsplit blocks) via global row/col indices derived from program_id.

VMEM per step (f32, d=256, 128×128 tiles): 128·256·4 × 2 + 128·128·4
≈ 320 KiB — far under the ~16 MiB/core budget; block sizes are exposed
for the §Perf sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pair_scores"]


def _kernel(a_ref, b_ref, o_ref, *, threshold: float, triangular: bool,
            block_m: int, block_n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    a = a_ref[...]                       # (block_m, d)
    b = b_ref[...]                       # (block_n, d)
    s = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (block_m, block_n) MXU
    keep = s >= threshold
    if triangular:
        rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keep = keep & (rows < cols)
    o_ref[...] = jnp.where(keep, s, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "triangular", "block_m", "block_n", "interpret"))
def pair_scores(a, b, *, threshold: float = 0.8, triangular: bool = False,
                block_m: int = 128, block_n: int = 128,
                interpret: bool = False):
    """Thresholded similarity scores of every (row of a) × (row of b).

    a: (M, d), b: (N, d) — rows L2-normalized. Returns (M, N) f32 with 0
    where score < threshold (or masked by x < y when ``triangular``).
    M, N are padded to tile multiples internally.
    """
    m, d = a.shape
    n = b.shape[0]
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    a_p = jnp.zeros((mp, d), a.dtype).at[:m].set(a)
    b_p = jnp.zeros((np_, d), b.dtype).at[:n].set(b)

    out = pl.pallas_call(
        functools.partial(
            _kernel, threshold=threshold, triangular=triangular,
            block_m=block_m, block_n=block_n),
        grid=(mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
