"""Pallas TPU kernel: grouped matmul for MoE expert FFNs.

Tokens arrive sorted by expert (the balanced dispatch built on the
paper's LPT/range machinery produces exactly this layout — experts are
"blocks", tokens are "entities"). Each expert's segment is padded to a
multiple of ``block_t`` on the host/jnp side, yielding a tile→expert map
``tile_expert`` (scalar-prefetch operand). The kernel grid is
(token_tiles, out_tiles); the BlockSpec index_map reads the expert id for
the current token tile from the prefetched map and pulls that expert's
weight strip into VMEM — a MegaBlocks-style block-diagonal GEMM without
materializing the (T, E, d) one-hot dispatch tensor.

VMEM per step (f32): block_t·d + d·block_f + block_t·block_f floats;
defaults (128, d≤4096, 128) ≈ 4.2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["grouped_matmul", "pad_groups"]


def _kernel(tile_expert_ref, x_ref, w_ref, o_ref):
    del tile_expert_ref  # consumed by the index_map only
    x = x_ref[...]                       # (block_t, d)
    w = w_ref[0]                         # (d, block_f)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def grouped_matmul(x, tile_expert, w, *, block_t: int = 128,
                   block_f: int = 128, interpret: bool = False):
    """x: (T, d) tokens, expert-sorted and tile-aligned (T % block_t == 0,
    all tokens in one tile belong to one expert). tile_expert: (T//block_t,)
    int32. w: (E, d, F). Returns (T, F) = x @ w[expert_of_token].
    """
    t, d = x.shape
    e, _, f = w.shape
    assert t % block_t == 0, "pad token count to a tile multiple (pad_groups)"
    fp = -(-f // block_f) * block_f
    w_p = jnp.zeros((e, d, fp), w.dtype).at[:, :, :f].set(w) if fp != f else w

    grid_spec = pl.GridSpec(
        grid=(t // block_t, fp // block_f),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, j, te: (i, 0)),
            pl.BlockSpec((1, d, block_f), lambda i, j, te: (te[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_f), lambda i, j, te: (i, j)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu_prefetch(grid_spec, num_scalar_prefetch=1),
        out_shape=jax.ShapeDtypeStruct((t, fp), x.dtype),
        interpret=interpret,
    )(tile_expert, x, w_p)
    return out[:, :f]


def pltpu_prefetch(grid_spec: pl.GridSpec, num_scalar_prefetch: int,
                   scratch_shapes=None):
    """Build a PrefetchScalarGridSpec from a plain GridSpec.

    ``scratch_shapes`` (e.g. ``pltpu.VMEM`` buffers and DMA semaphores
    for manual double-buffered strip copies) pass through verbatim.
    """
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=grid_spec.grid,
        in_specs=grid_spec.in_specs,
        out_specs=grid_spec.out_specs,
        scratch_shapes=tuple(scratch_shapes or ()),
    )


def pad_groups(x, group_sizes, block_t: int = 128):
    """Expert-sorted tokens + group sizes → tile-aligned layout.

    x: (T, d) sorted by expert; group_sizes: (E,) with sum T. Returns
    (x_padded (Tp, d), tile_expert (Tp//block_t,), token_map (Tp,) int32
    giving the source row of each padded row, −1 for padding).

    Host/jnp-side (shapes depend on values) — in the training path this
    runs under a fixed capacity so shapes stay static; see models/moe.py.
    """
    import numpy as np

    sizes = np.asarray(group_sizes, np.int64)
    e = sizes.shape[0]
    padded = -(-sizes // block_t) * block_t
    padded = np.maximum(padded, 0)
    tp = int(padded.sum()) if padded.sum() else block_t
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    pstarts = np.concatenate([[0], np.cumsum(padded)[:-1]])
    token_map = np.full(tp, -1, np.int64)
    tile_expert = np.zeros(tp // block_t, np.int32)
    for k in range(e):
        token_map[pstarts[k]: pstarts[k] + sizes[k]] = np.arange(
            starts[k], starts[k] + sizes[k])
        tile_expert[pstarts[k] // block_t: (pstarts[k] + padded[k]) // block_t] = k
    gathered = jnp.asarray(
        np.where(token_map[:, None] >= 0, 1, 0), x.dtype
    ) * x[jnp.asarray(np.maximum(token_map, 0))]
    return gathered, jnp.asarray(tile_expert), token_map
