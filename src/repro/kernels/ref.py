"""Pure-jnp oracles for every Pallas kernel (the allclose targets of the
per-kernel sweep tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pair_scores_ref", "grouped_matmul_ref", "attention_ref"]


def pair_scores_ref(a, b, *, threshold: float = 0.8, triangular: bool = False):
    """(M, d) × (N, d) → thresholded score matrix (M, N)."""
    s = jnp.einsum("md,nd->mn", a, b, preferred_element_type=jnp.float32)
    keep = s >= threshold
    if triangular:
        m, n = s.shape
        rows = jnp.arange(m)[:, None]
        cols = jnp.arange(n)[None, :]
        keep = keep & (rows < cols)
    return jnp.where(keep, s, 0.0)


def grouped_matmul_ref(x, tile_expert, w, *, block_t: int = 128):
    """x: (T, d) tile-aligned expert-sorted tokens; w: (E, d, F)."""
    t, _ = x.shape
    expert_of_token = jnp.repeat(tile_expert, block_t)
    w_tok = w[expert_of_token]                       # (T, d, F)
    return jnp.einsum("td,tdf->tf", x, w_tok,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, H, S, D); k, v: (B, KVH, S, D). Plain softmax attention."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(p.dtype)).astype(q.dtype)
