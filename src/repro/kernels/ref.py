"""Pure-jnp oracles for every Pallas kernel (the allclose targets of the
per-kernel sweep tests). ``pair_scores_catalog_ref`` doubles as the
production CPU/fallback path of the tile-catalog executor — a batched
matmul over dynamic-sliced strips, shape-stable, shard_map-safe."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["pair_scores_ref", "pair_scores_catalog_ref",
           "pair_scores_catalog_raw_ref", "pair_scores_catalog_compact_ref",
           "pack_survivor_mask",
           "grouped_matmul_ref", "attention_ref"]


def pair_scores_ref(a, b, *, threshold: float = 0.8, triangular: bool = False):
    """(M, d) × (N, d) → thresholded score matrix (M, N)."""
    s = jnp.einsum("md,nd->mn", a, b, preferred_element_type=jnp.float32)
    keep = s >= threshold
    if triangular:
        m, n = s.shape
        rows = jnp.arange(m)[:, None]
        cols = jnp.arange(n)[None, :]
        keep = keep & (rows < cols)
    return jnp.where(keep, s, 0.0)


@functools.partial(
    jax.jit, static_argnames=("threshold", "block_m", "block_n"))
def pair_scores_catalog_ref(a, b, catalog, *, threshold: float = 0.8,
                            block_m: int = 128, block_n: int = 128):
    """jnp twin of kernels.pair_sim.pair_scores_catalog: vmap over catalog
    entries, each gathering its two strips with ``dynamic_slice`` (the
    BlockSpec-index_map analog) — XLA lowers the batch to one grouped
    matmul. Same (T, bm, bn) f32 0/1 output."""
    from .pair_sim import catalog_tile_mask

    m, d = a.shape
    n = b.shape[0]
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    a_p = jnp.zeros((mp, d), a.dtype).at[:m].set(a)
    b_p = jnp.zeros((np_, d), b.dtype).at[:n].set(b)

    def one(entry):
        ai = jax.lax.dynamic_slice(a_p, (entry[0] * block_m, 0), (block_m, d))
        bi = jax.lax.dynamic_slice(b_p, (entry[1] * block_n, 0), (block_n, d))
        s = jax.lax.dot_general(
            ai, bi, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        gi = entry[0] * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        gj = entry[1] * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keep = (s >= threshold) & catalog_tile_mask(entry, gi, gj)
        return keep.astype(jnp.float32)

    return jax.vmap(one)(catalog)


def pair_scores_catalog_raw_ref(a, b, catalog, *, block_m: int = 128,
                                block_n: int = 128):
    """UNthresholded, UNmasked per-tile scores — the model-parallel
    partial-score path: each model shard holds a (rows, d/n_model) panel,
    so its dots are *partial sums* and neither the threshold nor the
    catalog predicates can be applied until a ``psum`` over the model
    axis combines them. Same dynamic-slice batched matmul as
    :func:`pair_scores_catalog_ref`, returning raw (T, bm, bn) f32 —
    shard_map-safe (no jit wrapper: the caller's shard body is the jit
    unit, and the post-psum threshold+mask epilogue lives there)."""
    m, d = a.shape
    n = b.shape[0]
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    a_p = jnp.zeros((mp, d), a.dtype).at[:m].set(a)
    b_p = jnp.zeros((np_, d), b.dtype).at[:n].set(b)

    def one(entry):
        ai = jax.lax.dynamic_slice(a_p, (entry[0] * block_m, 0), (block_m, d))
        bi = jax.lax.dynamic_slice(b_p, (entry[1] * block_n, 0), (block_n, d))
        return jax.lax.dot_general(
            ai, bi, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    return jax.vmap(one)(catalog)


def pack_survivor_mask(masks, capacity: int):
    """Dense (T, bm, bn) survivor masks → the kernel's ``(packed,
    counts)`` contract, via an inclusive row-major cumsum (pack slot =
    rank − 1) and a batched scatter with a dump slot at ``capacity`` that
    absorbs overflow survivors. Slots beyond min(count, capacity) stay 0,
    matching the Pallas epilogue exactly. Shared by
    :func:`pair_scores_catalog_compact_ref` and the model-sharded scorer
    (which must pack *after* its cross-shard psum)."""
    t = masks.shape[0]
    p = masks.shape[1] * masks.shape[2]
    flat = masks.reshape(t, p) > 0
    cum = jnp.cumsum(flat.astype(jnp.int32), axis=1)
    counts = cum[:, -1:]
    dest = jnp.where(flat, jnp.minimum(cum - 1, capacity), capacity)
    pos = jnp.broadcast_to(jnp.arange(p, dtype=jnp.int32), (t, p))
    packed = jnp.zeros((t, capacity + 1), jnp.int32)
    packed = packed.at[jnp.arange(t)[:, None], dest].set(
        jnp.where(flat, pos, 0))
    return packed[:, :capacity], counts


@functools.partial(
    jax.jit, static_argnames=("threshold", "block_m", "block_n", "capacity"))
def pair_scores_catalog_compact_ref(a, b, catalog, *, threshold: float = 0.8,
                                    block_m: int = 128, block_n: int = 128,
                                    capacity: int = 1024):
    """jnp twin of pair_sim.pair_scores_catalog_compact: same
    ``(packed, counts)`` contract — the mask from
    :func:`pair_scores_catalog_ref` packed by
    :func:`pack_survivor_mask`."""
    masks = pair_scores_catalog_ref(a, b, catalog, threshold=threshold,
                                    block_m=block_m, block_n=block_n)
    return pack_survivor_mask(masks, capacity)


def grouped_matmul_ref(x, tile_expert, w, *, block_t: int = 128):
    """x: (T, d) tile-aligned expert-sorted tokens; w: (E, d, F)."""
    t, _ = x.shape
    expert_of_token = jnp.repeat(tile_expert, block_t)
    w_tok = w[expert_of_token]                       # (T, d, F)
    return jnp.einsum("td,tdf->tf", x, w_tok,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, H, S, D); k, v: (B, KVH, S, D). Plain softmax attention."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(p.dtype)).astype(q.dtype)
