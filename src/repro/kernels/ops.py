"""jit'd public wrappers around the Pallas kernels, with XLA fallbacks.

Every op takes ``impl``:
  * "pallas"    — the TPU kernel (compiled; TPU target),
  * "interpret" — the kernel body interpreted on CPU (correctness path,
                  used by tests on this CPU-only container),
  * "xla"       — the pure-jnp reference (dry-run / fallback).

On a CPU backend "pallas" silently degrades to "interpret" so example
scripts run anywhere.
"""
from __future__ import annotations

import jax

from . import ref
from .flash_attention import flash_attention as _flash
from .grouped_mm import grouped_matmul as _gmm, pad_groups  # noqa: F401
from .pair_sim import pair_scores as _pair_scores
from .pair_sim import pair_scores_catalog as _pair_scores_catalog
from .pair_sim import \
    pair_scores_catalog_compact as _pair_scores_catalog_compact

__all__ = ["pair_scores", "pair_scores_catalog",
           "pair_scores_catalog_raw", "pair_scores_catalog_compact",
           "grouped_matmul", "attention", "pad_groups"]


def _resolve(impl: str) -> str:
    if impl == "pallas" and jax.default_backend() != "tpu":
        return "interpret"
    return impl


def pair_scores(a, b, *, threshold: float = 0.8, triangular: bool = False,
                block_m: int = 128, block_n: int = 128, impl: str = "pallas"):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.pair_scores_ref(a, b, threshold=threshold, triangular=triangular)
    return _pair_scores(a, b, threshold=threshold, triangular=triangular,
                        block_m=block_m, block_n=block_n,
                        interpret=(impl == "interpret"))


def pair_scores_catalog(a, b, catalog, *, threshold: float = 0.8,
                        block_m: int = 128, block_n: int = 128,
                        impl: str = "pallas"):
    """Tile-catalog survivor masks (see pair_sim.pair_scores_catalog).
    ``impl="xla"`` is the production CPU path (batched dynamic-slice
    matmul), not just a test oracle — interpret mode is Python-slow."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.pair_scores_catalog_ref(
            a, b, catalog, threshold=threshold,
            block_m=block_m, block_n=block_n)
    return _pair_scores_catalog(a, b, catalog, threshold=threshold,
                                block_m=block_m, block_n=block_n,
                                interpret=(impl == "interpret"))


def pair_scores_catalog_raw(a, b, catalog, *, block_m: int = 128,
                            block_n: int = 128, impl: str = "pallas"):
    """UNthresholded, UNmasked tile scores (see
    ref.pair_scores_catalog_raw_ref) — the model-parallel partial-score
    path, where the threshold and the catalog predicates only make sense
    AFTER a psum over the model axis. Every ``impl`` routes to the
    batched dynamic-slice ``dot_general`` — on any backend that matmul IS
    the MXU/compute path; a fused Pallas raw variant would only re-fuse
    the slice, and the predicate epilogue it normally fuses is exactly
    what partial scores must defer."""
    del impl
    return ref.pair_scores_catalog_raw_ref(
        a, b, catalog, block_m=block_m, block_n=block_n)


def pair_scores_catalog_compact(a, b, catalog, *, threshold: float = 0.8,
                                block_m: int = 128, block_n: int = 128,
                                capacity: int = 1024, impl: str = "pallas"):
    """Tile-catalog survivors packed on device (see
    pair_sim.pair_scores_catalog_compact): ``(packed, counts)`` instead
    of a dense mask — the serving stage 1 uses this so the host never
    runs ``np.nonzero`` over T·bm·bn cells."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.pair_scores_catalog_compact_ref(
            a, b, catalog, threshold=threshold,
            block_m=block_m, block_n=block_n, capacity=capacity)
    return _pair_scores_catalog_compact(
        a, b, catalog, threshold=threshold, block_m=block_m,
        block_n=block_n, capacity=capacity,
        interpret=(impl == "interpret"))


def grouped_matmul(x, tile_expert, w, *, block_t: int = 128,
                   block_f: int = 128, impl: str = "pallas"):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.grouped_matmul_ref(x, tile_expert, w, block_t=block_t)
    return _gmm(x, tile_expert, w, block_t=block_t, block_f=block_f,
                interpret=(impl == "interpret"))


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              block_q: int = 512, block_k: int = 512, impl: str = "xla"):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)
    return _flash(q, k, v, causal=causal, scale=scale,
                  block_q=block_q, block_k=block_k,
                  interpret=(impl == "interpret"))
